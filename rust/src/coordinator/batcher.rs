//! Dynamic batching module (paper Fig. 3): drains a model's priority
//! queue into up to m_c instance-batches of up to b requests each, and
//! pads each batch to the nearest compiled artifact size (the
//! TensorRT-engine-per-batch analogue — see DESIGN.md §2).

use super::queue::ModelQueue;
use crate::workload::request::Request;

/// One assembled instance-batch.
#[derive(Clone, Debug)]
pub struct AssembledBatch {
    pub requests: Vec<Request>,
    /// Execution batch size after padding (≥ requests.len()).
    pub padded: usize,
}

impl AssembledBatch {
    pub fn n_real(&self) -> usize {
        self.requests.len()
    }
}

/// Split policy + padding for one scheduling slot.
#[derive(Clone, Copy, Debug)]
pub struct Batcher {
    /// Compiled batch sizes, ascending (None entries pad to exact size —
    /// the simulator executes any batch size).
    pub compiled: Option<[usize; 6]>,
}

impl Batcher {
    /// Batcher padding to the standard AOT grid {1,2,4,8,16,32}.
    pub fn for_artifacts() -> Self {
        Batcher { compiled: Some([1, 2, 4, 8, 16, 32]) }
    }

    /// Simulator batcher: no padding constraint.
    pub fn exact() -> Self {
        Batcher { compiled: None }
    }

    /// Pad a real batch size up to the nearest compiled size (clamping to
    /// the largest compiled engine).
    pub fn pad(&self, n: usize) -> usize {
        assert!(n > 0);
        match &self.compiled {
            None => n,
            Some(sizes) => *sizes
                .iter()
                .find(|&&s| s >= n)
                .unwrap_or(sizes.last().unwrap()),
        }
    }

    /// Drain up to `b × m_c` requests from `queue` and split them into at
    /// most `m_c` batches of at most `b` (paper Fig. 3: the dynamically
    /// created batches are distributed to all configured instances).
    /// Requests keep priority order: batch 0 gets the most urgent block.
    ///
    /// Writes into a caller-owned buffer, reusing both the
    /// `AssembledBatch` entries and their inner request `Vec`s — the
    /// engine recycles one buffer per model slot across rounds, so
    /// steady-state assembly allocates nothing. Any pre-existing entries
    /// in `out` must already be empty of requests (the engine clears them
    /// on recycle).
    pub fn assemble_into(&self, queue: &mut ModelQueue, b: usize,
                         m_c: usize, out: &mut Vec<AssembledBatch>) {
        assert!(b > 0 && m_c > 0);
        // A chunk can never exceed the largest compiled engine — a
        // scheduler asking for more gets the engine ceiling (TensorRT
        // behaviour), not an unservable batch.
        let b = match &self.compiled {
            None => b,
            Some(sizes) => b.min(*sizes.last().unwrap()),
        };
        let mut remaining = (b * m_c).min(queue.len());
        let mut used = 0;
        while remaining > 0 {
            let n = remaining.min(b);
            if used == out.len() {
                out.push(AssembledBatch {
                    requests: Vec::with_capacity(n),
                    padded: 0,
                });
            }
            let batch = &mut out[used];
            batch.requests.clear();
            for _ in 0..n {
                batch.requests.push(queue.pop().expect("queue under-count"));
            }
            batch.padded = self.pad(n);
            used += 1;
            remaining -= n;
        }
        out.truncate(used);
    }

    /// Allocating convenience wrapper over [`Batcher::assemble_into`].
    pub fn assemble(&self, queue: &mut ModelQueue, b: usize, m_c: usize)
                    -> Vec<AssembledBatch> {
        let mut out = Vec::new();
        self.assemble_into(queue, b, m_c, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::ModelId;

    fn filled_queue(n: usize) -> ModelQueue {
        let mut q = ModelQueue::new();
        for id in 0..n as u64 {
            q.push(Request::new(id, ModelId::Res, id as f64));
        }
        q
    }

    #[test]
    fn splits_into_instance_batches() {
        let mut q = filled_queue(10);
        let batches = Batcher::exact().assemble(&mut q, 4, 2);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].n_real(), 4);
        assert_eq!(batches[1].n_real(), 4);
        assert_eq!(q.len(), 2); // leftovers stay queued
    }

    #[test]
    fn underfull_queue_yields_partial_batches() {
        let mut q = filled_queue(3);
        let batches = Batcher::exact().assemble(&mut q, 4, 2);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].n_real(), 3);
        assert!(q.is_empty());
    }

    #[test]
    fn empty_queue_yields_no_batches() {
        let mut q = ModelQueue::new();
        assert!(Batcher::exact().assemble(&mut q, 8, 4).is_empty());
    }

    #[test]
    fn padding_to_compiled_sizes() {
        let b = Batcher::for_artifacts();
        assert_eq!(b.pad(1), 1);
        assert_eq!(b.pad(3), 4);
        assert_eq!(b.pad(5), 8);
        assert_eq!(b.pad(32), 32);
        assert_eq!(b.pad(100), 32); // clamp to largest engine
        assert_eq!(Batcher::exact().pad(100), 100);
    }

    #[test]
    fn conservation_no_drop_no_dup() {
        let mut q = filled_queue(9);
        let batches = Batcher::exact().assemble(&mut q, 4, 3);
        let mut ids: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id))
            .collect();
        ids.extend(q.drain(q.len()).iter().map(|r| r.id));
        ids.sort_unstable();
        assert_eq!(ids, (0..9).collect::<Vec<u64>>());
    }

    #[test]
    fn assemble_into_reuses_buffer_and_matches_assemble() {
        let mut buf = Vec::new();
        for round in 0..4 {
            let mut q_into = filled_queue(9 + round);
            let mut q_alloc = filled_queue(9 + round);
            Batcher::exact().assemble_into(&mut q_into, 4, 3, &mut buf);
            let fresh = Batcher::exact().assemble(&mut q_alloc, 4, 3);
            assert_eq!(buf.len(), fresh.len());
            for (a, b) in buf.iter().zip(&fresh) {
                assert_eq!(a.padded, b.padded);
                assert_eq!(a.requests, b.requests);
            }
            assert_eq!(q_into.len(), q_alloc.len());
            // Recycle like the engine does: clear requests, keep buffers.
            for b in buf.iter_mut() {
                b.requests.clear();
            }
        }
    }

    #[test]
    fn priority_block_goes_to_first_instance() {
        let mut q = ModelQueue::new();
        let mut urgent = Request::new(99, ModelId::Res, 100.0);
        urgent.slo_ms = 5.0;
        q.push(Request::new(1, ModelId::Res, 0.0));
        q.push(urgent);
        let batches = Batcher::exact().assemble(&mut q, 1, 2);
        assert_eq!(batches[0].requests[0].id, 99);
    }
}
