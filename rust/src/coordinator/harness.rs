//! Experiment harness shared by the figure benches: one call = one
//! simulated serving run (platform × scheduler × workload), returning the
//! engine's metrics. Keeps every `rust/benches/fig*.rs` small and makes
//! runs comparable (same trace seed ⇒ identical arrivals across
//! schedulers, as the paper's comparisons require).

use super::baselines::{self, AgentScheduler, DeepRtScheduler, FixedScheduler};
use super::engine::{Engine, EngineConfig};
use super::sac_sched::{self, SchedEnv};
use super::scheduler::{Scheduler, STATE_DIM};
use crate::metrics::Metrics;
use crate::platform::{PlatformSim, PlatformSpec};
use crate::rl::ac::{AcConfig, ActorCritic};
use crate::rl::ddqn::{Ddqn, DdqnConfig};
use crate::rl::env::{train_episodes, Agent};
use crate::rl::ppo::{Ppo, PpoConfig};
use crate::rl::sac::{DiscreteSac, SacConfig};
use crate::rl::spaces::ActionSpace;
use crate::runtime::executor::SimDispatcher;
use crate::util::rng::Pcg32;
use crate::util::time::VirtualClock;
use crate::workload::generator::PoissonGenerator;
use crate::workload::models::ModelId;

/// Scheduler selector for experiment matrices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedKind {
    /// BCEdge: discrete SAC with entropy (the paper's system).
    Sac,
    /// Triton + actor-critic without entropy.
    Tac,
    /// DeepRT: EDF batching, no concurrency.
    DeepRt,
    /// Static Triton config.
    Fixed,
    Ddqn,
    Ppo,
}

impl SchedKind {
    pub fn label(&self) -> &'static str {
        match self {
            SchedKind::Sac => "BCEdge",
            SchedKind::Tac => "TAC",
            SchedKind::DeepRt => "DeepRT",
            SchedKind::Fixed => "Fixed",
            SchedKind::Ddqn => "DDQN",
            SchedKind::Ppo => "PPO",
        }
    }

    pub fn build(&self, space: &ActionSpace, rng: &mut Pcg32)
                 -> Box<dyn Scheduler> {
        match self {
            SchedKind::Sac => Box::new(sac_sched::sac(space.clone(), rng)),
            SchedKind::Tac => Box::new(baselines::tac(space.clone(), rng)),
            SchedKind::DeepRt => Box::new(DeepRtScheduler::default()),
            SchedKind::Fixed => Box::new(FixedScheduler { batch: 4, m_c: 2 }),
            SchedKind::Ddqn => Box::new(baselines::ddqn(space.clone(), rng)),
            SchedKind::Ppo => Box::new(baselines::ppo(space.clone(), rng)),
        }
    }
}

/// One experiment configuration.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub sched: SchedKind,
    pub platform: PlatformSpec,
    /// Offered rate PER MODEL, requests/s (the paper's "30 rps" read
    /// per-model: its Fig. 8 shows tens of completions/s for each model
    /// simultaneously, which only an aggregate of ~6 × 30 rps produces).
    /// Aggregate offered load = rps × |models|.
    pub rps: f64,
    pub horizon_s: f64,
    pub use_predictor: bool,
    /// Restrict traffic to a model subset (Figs. 11/12 use 3 models).
    pub models: Option<Vec<ModelId>>,
    /// Trace seed: equal seeds ⇒ identical arrival processes.
    pub seed: u64,
    /// Offline-training episodes for learning schedulers before the
    /// measured window (the paper trains offline on a GPU rig, then
    /// deploys; heuristics ignore this).
    pub pretrain_episodes: usize,
}

impl Experiment {
    pub fn new(sched: SchedKind) -> Self {
        Experiment {
            sched,
            platform: PlatformSpec::xavier_nx(),
            rps: 15.0,
            horizon_s: 300.0,
            use_predictor: true,
            models: None,
            seed: 7,
            pretrain_episodes: 25,
        }
    }

    /// Build the scheduler, running the offline-training phase for
    /// learning agents (equal episode budget for every learner).
    fn build_scheduler(&self, space: &ActionSpace, rng: &mut Pcg32)
                       -> Box<dyn Scheduler> {
        let n = space.len();
        fn pretrain<A: Agent>(agent: &mut A, exp: &Experiment,
                              space: &ActionSpace, rng: &mut Pcg32) {
            if exp.pretrain_episodes == 0 {
                return;
            }
            let mut env =
                SchedEnv::new(space.clone(), exp.rps, exp.platform.clone());
            env.model_subset = exp.models.clone();
            env.episode_len = 96;
            train_episodes(&mut env, agent, exp.pretrain_episodes, 96, rng);
        }
        // After offline training every learner deploys GREEDILY w.r.t. its
        // policy (the paper's train-offline/deploy-online protocol) while
        // online fine-tuning continues through feedback; exploration noise
        // does not pollute the measured window.
        let mut sched: Box<dyn Scheduler> = match self.sched {
            SchedKind::Sac => {
                let mut agent = DiscreteSac::new(
                    STATE_DIM, n,
                    SacConfig { warmup: 128, ..Default::default() }, rng);
                pretrain(&mut agent, self, space, rng);
                Box::new(AgentScheduler::new(agent, space.clone(),
                                             "BCEdge (discrete SAC)"))
            }
            SchedKind::Tac => {
                let mut agent =
                    ActorCritic::new(STATE_DIM, n, AcConfig::default(), rng);
                pretrain(&mut agent, self, space, rng);
                Box::new(AgentScheduler::new(agent, space.clone(),
                                             "TAC (Triton + actor-critic)"))
            }
            SchedKind::Ddqn => {
                let mut agent =
                    Ddqn::new(STATE_DIM, n, DdqnConfig::default(), rng);
                pretrain(&mut agent, self, space, rng);
                Box::new(AgentScheduler::new(agent, space.clone(), "DDQN"))
            }
            SchedKind::Ppo => {
                let mut agent =
                    Ppo::new(STATE_DIM, n, PpoConfig::default(), rng);
                pretrain(&mut agent, self, space, rng);
                Box::new(AgentScheduler::new(agent, space.clone(), "PPO"))
            }
            SchedKind::DeepRt => Box::new(DeepRtScheduler::default()),
            SchedKind::Fixed => Box::new(FixedScheduler { batch: 4, m_c: 2 }),
        };
        sched.set_greedy(true);
        sched
    }

    /// Run on the virtual-time simulator; returns final metrics.
    pub fn run(&self) -> Metrics {
        let space = ActionSpace::standard();
        let clock = VirtualClock::new();
        let dispatcher =
            SimDispatcher::new(PlatformSim::new(self.platform.clone()), clock);
        // Paper Table I: interference prediction is a BCEdge feature —
        // TAC/DeepRT/Triton do not have it, so only SAC runs get the
        // predictor veto (fig. 14 disables it explicitly to measure its
        // contribution).
        let predictor_on =
            self.use_predictor && matches!(self.sched, SchedKind::Sac);
        let mut engine = Engine::new(
            dispatcher,
            EngineConfig {
                action_space: space.clone(),
                use_predictor: predictor_on,
                pad_to_artifacts: false,
                max_total_instances: self.platform.max_instances,
                learn: true,
                seed: self.seed ^ 0xE17,
                ..Default::default()
            },
        );
        let n_models = self.models.as_ref().map(|m| m.len()).unwrap_or(6);
        let mut gen =
            PoissonGenerator::new(self.rps * n_models as f64, self.seed);
        if let Some(models) = &self.models {
            gen = gen.with_models(models);
        }
        engine.submit(gen.generate_horizon(self.horizon_s * 1e3));
        let mut rng = Pcg32::seeded(self.seed ^ 0x5ced);
        let mut scheduler = self.build_scheduler(&space, &mut rng);
        engine.run(scheduler.as_mut(), self.horizon_s * 1e3);
        engine.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn experiment_runs_all_schedulers() {
        for kind in [SchedKind::Sac, SchedKind::Tac, SchedKind::DeepRt,
                     SchedKind::Fixed] {
            let mut e = Experiment::new(kind);
            e.horizon_s = 20.0;
            let m = e.run();
            assert!(m.completed() > 0, "{kind:?} served nothing");
            assert!(m.violation_rate() <= 1.0);
        }
    }

    #[test]
    fn same_seed_identical_arrivals_same_scheduler() {
        // Same seed + same scheduler ⇒ bit-identical run (the property
        // scheduler comparisons rely on: only the policy varies).
        let mut a = Experiment::new(SchedKind::Fixed);
        a.horizon_s = 20.0;
        let mut b = Experiment::new(SchedKind::Fixed);
        b.horizon_s = 20.0;
        let (ma, mb) = (a.run(), b.run());
        assert_eq!(ma.outcomes().len(), mb.outcomes().len());
        assert_eq!(ma.completed(), mb.completed());
        assert!((ma.mean_latency_ms(None) - mb.mean_latency_ms(None)).abs()
                < 1e-9);
    }
}
