//! Concurrent instance management (paper Fig. 4 / §IV-D): tracks the
//! instance slots configured per model, enforces the platform's instance
//! cap, and serializes same-model overflow ("if multiple inference
//! requests for the same model arrive at the same time, BCEdge serializes
//! their execution by scheduling only one at a time" per instance).

use crate::workload::models::{ModelId, N_MODELS};

/// Per-model instance-slot registry.
#[derive(Clone, Debug)]
pub struct InstanceManager {
    /// Configured instance count per model (the m_c the scheduler chose
    /// most recently).
    configured: [usize; N_MODELS],
    /// Currently-executing instances per model.
    active: [usize; N_MODELS],
    /// Platform-wide cap on simultaneously active instances.
    max_total: usize,
}

impl InstanceManager {
    pub fn new(max_total: usize) -> Self {
        InstanceManager {
            configured: [1; N_MODELS],
            active: [0; N_MODELS],
            max_total: max_total.max(1),
        }
    }

    /// Apply a scheduler decision for `model`.
    pub fn configure(&mut self, model: ModelId, m_c: usize) {
        self.configured[model as usize] = m_c.max(1);
    }

    pub fn configured(&self, model: ModelId) -> usize {
        self.configured[model as usize]
    }

    pub fn total_active(&self) -> usize {
        self.active.iter().sum()
    }

    pub fn active(&self, model: ModelId) -> usize {
        self.active[model as usize]
    }

    /// How many instance-batches of `model` may launch right now: bounded
    /// by the model's configuration and the platform-wide cap.
    pub fn admissible(&self, model: ModelId) -> usize {
        let per_model =
            self.configured[model as usize].saturating_sub(self.active[model as usize]);
        let global = self.max_total.saturating_sub(self.total_active());
        per_model.min(global)
    }

    /// Mark `n` instances of `model` as executing.
    pub fn acquire(&mut self, model: ModelId, n: usize) {
        assert!(n <= self.admissible(model), "over-admission");
        self.active[model as usize] += n;
    }

    /// Mark `n` instances of `model` as finished.
    pub fn release(&mut self, model: ModelId, n: usize) {
        let a = &mut self.active[model as usize];
        assert!(*a >= n, "releasing more instances than active");
        *a -= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_respects_both_caps() {
        let mut im = InstanceManager::new(4);
        im.configure(ModelId::Yolo, 3);
        im.configure(ModelId::Res, 3);
        assert_eq!(im.admissible(ModelId::Yolo), 3);
        im.acquire(ModelId::Yolo, 3);
        // Global cap 4, 3 in use → only 1 slot left for res despite m_c=3.
        assert_eq!(im.admissible(ModelId::Res), 1);
        im.acquire(ModelId::Res, 1);
        assert_eq!(im.admissible(ModelId::Res), 0);
        im.release(ModelId::Yolo, 3);
        assert_eq!(im.admissible(ModelId::Res), 2);
    }

    #[test]
    fn same_model_serializes_beyond_configuration() {
        let mut im = InstanceManager::new(8);
        im.configure(ModelId::Bert, 2);
        im.acquire(ModelId::Bert, 2);
        // Third simultaneous bert batch must wait (Fig. 4 semantics).
        assert_eq!(im.admissible(ModelId::Bert), 0);
    }

    #[test]
    #[should_panic(expected = "over-admission")]
    fn over_acquire_panics() {
        let mut im = InstanceManager::new(2);
        im.configure(ModelId::Mob, 4);
        im.acquire(ModelId::Mob, 3);
    }

    #[test]
    #[should_panic(expected = "releasing more")]
    fn over_release_panics() {
        let mut im = InstanceManager::new(2);
        im.release(ModelId::Mob, 1);
    }
}
