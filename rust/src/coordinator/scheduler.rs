//! Scheduler interface + the MDP state encoding of paper §IV-B.
//!
//! State sₜ (paper: five parts): (I) DNN model type, (II) input
//! type/shape, (III) per-request SLO, (IV) available computing resources,
//! (V) request-queue information — encoded as a fixed-width normalized
//! vector shared by the SAC scheduler, every DRL baseline, and the
//! interference predictor's context.

use crate::util::rng::Pcg32;
use crate::workload::models::{ModelId, ModelSpec, N_MODELS};

/// Encoded-state width: one-hot model (6) + 13 scalar features (10 local
/// + 2 cross-worker gauge hints + 1 replica share).
pub const STATE_DIM: usize = N_MODELS + 13;

/// Everything the scheduler can observe for one decision.
#[derive(Clone, Copy, Debug)]
pub struct SchedCtx {
    pub model: ModelId,
    pub queue_len: usize,
    /// Slack of the tightest queued deadline, ms (negative = already late).
    pub min_slack_ms: f64,
    /// The model's Table-IV SLO, ms.
    pub slo_ms: f64,
    /// Free memory fraction ∈ [0, 1].
    pub mem_free_frac: f64,
    /// Aggregate compute demand currently executing.
    pub compute_demand: f64,
    pub active_instances: usize,
    /// Rolling profiler views (NaN-safe: 0 when unobserved).
    pub recent_latency_ms: f64,
    pub recent_throughput_rps: f64,
    pub recent_inflation: f64,
    /// Cross-worker gauge hints (serving runtime): estimated backlog-ms
    /// across the WHOLE worker pool, and this worker's share of it. Both
    /// are 0.0 on the bare single-threaded engine and whenever the
    /// serving runtime's gauge hints are disabled, so their encoded
    /// features vanish and decisions reduce to the local-only view.
    pub cluster_backlog_ms: f64,
    /// This worker's fraction of `cluster_backlog_ms` ∈ [0, 1] (0 when
    /// the cluster view is absent or empty).
    pub cluster_share: f64,
    /// How widely this model's intake is replicated across the worker
    /// pool ∈ [0, 1]: 0 = one drainer (sole ownership — always the case
    /// on the bare engine, at `workers == 1`, and whenever the serving
    /// runtime's pool-state hints are disabled, so the feature vanishes
    /// and decisions reduce to the local-only view), 1 = every worker
    /// drains it. A replicated model's local queue understates its real
    /// demand (the pool splits it), which is exactly what the scheduler
    /// needs to see to keep batch sizing honest.
    pub replica_share: f64,
}

impl SchedCtx {
    /// Normalize into the fixed-width state vector.
    pub fn encode(&self) -> [f32; STATE_DIM] {
        let mut s = [0.0f32; STATE_DIM];
        s[self.model as usize] = 1.0;
        let spec = ModelSpec::get(self.model);
        let f = &mut s[N_MODELS..];
        f[0] = (self.queue_len as f32 / 64.0).min(2.0);
        f[1] = (self.min_slack_ms as f32 / self.slo_ms as f32).clamp(-1.0, 1.0);
        f[2] = self.slo_ms as f32 / 138.0; // max Table-IV SLO
        f[3] = (spec.input_elems as f32 / 3072.0).min(1.0);
        f[4] = self.mem_free_frac as f32;
        f[5] = (self.compute_demand as f32 / 8.0).min(2.0);
        f[6] = self.active_instances as f32 / 8.0;
        f[7] = nan0(self.recent_latency_ms as f32 / self.slo_ms as f32).min(3.0);
        f[8] = nan0(self.recent_throughput_rps as f32 / 200.0).min(3.0);
        f[9] = nan0(self.recent_inflation as f32 - 1.0).min(3.0);
        f[10] = nan0(self.cluster_share as f32).clamp(0.0, 1.0);
        f[11] = nan0((self.cluster_backlog_ms / 1e3) as f32).clamp(0.0, 3.0);
        f[12] = nan0(self.replica_share as f32).clamp(0.0, 1.0);
        s
    }
}

fn nan0(x: f32) -> f32 {
    if x.is_finite() { x } else { 0.0 }
}

/// A scheduling policy: observes the context, picks (batch, m_c), and
/// (for learners) consumes reward feedback.
pub trait Scheduler {
    /// Decide (batch size, number of concurrent instances).
    fn decide(&mut self, ctx: &SchedCtx, rng: &mut Pcg32) -> (usize, usize);

    /// Reward feedback for the *previous* decision (learning schedulers
    /// update here; heuristics ignore it). Returns a training loss for
    /// convergence plots, 0.0 when not learning.
    fn feedback(&mut self, prev: &SchedCtx, action: (usize, usize),
                reward: f64, next: &SchedCtx, done: bool, rng: &mut Pcg32)
                -> f32 {
        let _ = (prev, action, reward, next, done, rng);
        0.0
    }

    /// Switch exploration off (deployment mode).
    fn set_greedy(&mut self, greedy: bool) {
        let _ = greedy;
    }

    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> SchedCtx {
        SchedCtx {
            model: ModelId::Bert,
            queue_len: 16,
            min_slack_ms: 57.0,
            slo_ms: 114.0,
            mem_free_frac: 0.7,
            compute_demand: 1.5,
            active_instances: 2,
            recent_latency_ms: 30.0,
            recent_throughput_rps: 50.0,
            recent_inflation: 1.2,
            cluster_backlog_ms: 0.0,
            cluster_share: 0.0,
            replica_share: 0.0,
        }
    }

    #[test]
    fn encoding_shape_and_one_hot() {
        let s = ctx().encode();
        assert_eq!(s.len(), STATE_DIM);
        let one_hot: Vec<f32> = s[..N_MODELS].to_vec();
        assert_eq!(one_hot.iter().filter(|&&x| x == 1.0).count(), 1);
        assert_eq!(one_hot[ModelId::Bert as usize], 1.0);
    }

    #[test]
    fn encoding_is_bounded() {
        let mut c = ctx();
        c.queue_len = 100_000;
        c.recent_latency_ms = 1e9;
        c.recent_inflation = 1e9;
        c.min_slack_ms = -1e9;
        c.cluster_backlog_ms = 1e12;
        c.cluster_share = 1e9;
        c.replica_share = 1e9;
        let s = c.encode();
        assert!(s.iter().all(|x| x.is_finite() && x.abs() <= 3.0),
                "unbounded features: {s:?}");
    }

    /// Cross-worker gauge hints occupy the two new feature slots and
    /// vanish at their 0.0 default, so bare-engine encodings are the
    /// hint-free encodings with two zero features appended.
    #[test]
    fn cluster_hint_features_encode_and_default_to_zero() {
        let base = ctx().encode();
        assert_eq!(base[N_MODELS + 10], 0.0);
        assert_eq!(base[N_MODELS + 11], 0.0);
        let mut c = ctx();
        c.cluster_share = 0.5;
        c.cluster_backlog_ms = 800.0;
        let s = c.encode();
        assert!((s[N_MODELS + 10] - 0.5).abs() < 1e-6);
        assert!((s[N_MODELS + 11] - 0.8).abs() < 1e-6);
        // Every other feature is untouched by the hints.
        assert_eq!(&s[..N_MODELS + 10], &base[..N_MODELS + 10]);
        // NaN hints are scrubbed like every other feature.
        c.cluster_share = f64::NAN;
        c.cluster_backlog_ms = f64::NAN;
        assert!(c.encode().iter().all(|x| x.is_finite()));
    }

    /// The replica-share feature occupies the last slot and vanishes at
    /// its 0.0 default, so sole-owner (and bare-engine) encodings are
    /// the pre-replication encodings with one zero feature appended.
    #[test]
    fn replica_share_feature_encodes_and_defaults_to_zero() {
        let base = ctx().encode();
        assert_eq!(base[N_MODELS + 12], 0.0);
        let mut c = ctx();
        c.replica_share = 0.75;
        let s = c.encode();
        assert!((s[N_MODELS + 12] - 0.75).abs() < 1e-6);
        // Every other feature is untouched by the replica share.
        assert_eq!(&s[..N_MODELS + 12], &base[..N_MODELS + 12]);
        // NaN shares are scrubbed like every other feature.
        c.replica_share = f64::NAN;
        assert!(c.encode().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn nan_features_become_zero() {
        let mut c = ctx();
        c.recent_latency_ms = f64::NAN;
        c.recent_throughput_rps = f64::NAN;
        let s = c.encode();
        assert!(s.iter().all(|x| x.is_finite()));
    }
}
