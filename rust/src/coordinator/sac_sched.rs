//! The BCEdge learning-based scheduler (paper §IV-B / Algorithm 1):
//! discrete SAC behind the [`Scheduler`] trait, plus [`SchedEnv`] — the
//! offline-training environment that exposes the serving engine as an
//! [`Env`] so Algorithm 1 can run against the platform simulator ("we
//! trained it offline on an off-the-edge device … then deploy trained
//! algorithm online to edge platform").

use super::baselines::AgentScheduler;
use super::engine::{Engine, EngineConfig};
use super::scheduler::STATE_DIM;
use crate::platform::{PlatformSim, PlatformSpec};
use crate::rl::env::{Env, Step};
use crate::rl::sac::{DiscreteSac, SacConfig};
use crate::rl::spaces::ActionSpace;
use crate::runtime::executor::SimDispatcher;
use crate::util::rng::Pcg32;
use crate::util::time::VirtualClock;
use crate::workload::generator::PoissonGenerator;
use crate::workload::models::ModelId;

/// BCEdge's scheduler: maximum-entropy discrete SAC on the 2-D action
/// grid.
pub type SacScheduler = AgentScheduler<DiscreteSac>;

/// Construct the SAC scheduler (paper defaults).
pub fn sac(space: ActionSpace, rng: &mut Pcg32) -> SacScheduler {
    sac_with(space, SacConfig::default(), rng)
}

/// Construct with explicit SAC hyper-parameters.
pub fn sac_with(space: ActionSpace, cfg: SacConfig, rng: &mut Pcg32)
                -> SacScheduler {
    let agent = DiscreteSac::new(STATE_DIM, space.len(), cfg, rng);
    AgentScheduler::new(agent, space, "BCEdge (discrete SAC)")
}

/// Offline-training MDP over the simulated platform: each step is one
/// scheduling slot on a Poisson-fed engine; reward is the Eq. (6) slot
/// reward. Episodes restart the engine with fresh traffic.
pub struct SchedEnv {
    pub space: ActionSpace,
    pub rps: f64,
    pub platform: PlatformSpec,
    /// Steps per episode.
    pub episode_len: usize,
    engine: Engine<SimDispatcher>,
    current_model: Option<ModelId>,
    steps: usize,
    episode: u64,
    /// Restrict generated traffic to a model subset (None = full zoo).
    pub model_subset: Option<Vec<ModelId>>,
}

impl SchedEnv {
    pub fn new(space: ActionSpace, rps: f64, platform: PlatformSpec) -> Self {
        let engine = Self::fresh_engine(&space, rps, &platform, 0, &None);
        SchedEnv {
            space,
            rps,
            platform,
            episode_len: 128,
            engine,
            current_model: None,
            steps: 0,
            episode: 0,
            model_subset: None,
        }
    }

    fn fresh_engine(space: &ActionSpace, rps: f64, platform: &PlatformSpec,
                    episode: u64, subset: &Option<Vec<ModelId>>)
                    -> Engine<SimDispatcher> {
        let clock = VirtualClock::new();
        let dispatcher =
            SimDispatcher::new(PlatformSim::new(platform.clone()), clock);
        let mut engine = Engine::new(
            dispatcher,
            EngineConfig {
                action_space: space.clone(),
                // During offline training the predictor is disabled so the
                // agent sees raw consequences (the predictor is layered on
                // at deployment, §IV-F).
                use_predictor: false,
                pad_to_artifacts: false,
                max_total_instances: platform.max_instances,
                learn: false, // learning happens through the Env interface
                ..Default::default()
            },
        );
        // `rps` is per-model (see harness::Experiment::rps).
        let n_models = subset.as_ref().map(|m| m.len()).unwrap_or(6);
        let mut gen = PoissonGenerator::new(rps * n_models as f64,
                                            0x5EED ^ episode);
        if let Some(models) = subset {
            gen = gen.with_models(models);
        }
        // Enough traffic that an episode never starves (episodes are
        // step-bounded, not horizon-bounded).
        engine.submit(gen.generate_horizon(600_000.0));
        engine
    }

    /// Access the inner engine (diagnostics / tests).
    pub fn engine(&self) -> &Engine<SimDispatcher> {
        &self.engine
    }
}

impl Env for SchedEnv {
    fn state_dim(&self) -> usize {
        STATE_DIM
    }

    fn n_actions(&self) -> usize {
        self.space.len()
    }

    fn reset(&mut self, _rng: &mut Pcg32) -> Vec<f32> {
        self.episode += 1;
        self.engine = Self::fresh_engine(
            &self.space,
            self.rps,
            &self.platform,
            self.episode,
            &self.model_subset,
        );
        self.steps = 0;
        let model = self.engine.next_model().expect("traffic exhausted");
        self.current_model = Some(model);
        self.engine.ctx_for(model).encode().to_vec()
    }

    fn step(&mut self, action: usize, _rng: &mut Pcg32) -> Step {
        let model = self.current_model.expect("step before reset");
        let (b, m_c) = self.space.decode(action);
        let outcome = self.engine.execute_slot(model, b, m_c);
        self.steps += 1;
        let done = self.steps >= self.episode_len;
        let next_model = if done {
            model
        } else {
            self.engine.next_model().unwrap_or(model)
        };
        self.current_model = Some(next_model);
        Step {
            next_state: self.engine.ctx_for(next_model).encode().to_vec(),
            reward: outcome.reward as f32,
            done,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::env::{train_episodes, Agent};

    #[test]
    fn env_round_trip() {
        let mut rng = Pcg32::seeded(101);
        let mut env = SchedEnv::new(ActionSpace::standard(), 30.0,
                                    PlatformSpec::xavier_nx());
        let s = env.reset(&mut rng);
        assert_eq!(s.len(), STATE_DIM);
        let step = env.step(0, &mut rng);
        assert_eq!(step.next_state.len(), STATE_DIM);
        assert!(step.reward.is_finite());
    }

    #[test]
    fn sac_improves_scheduling_reward() {
        let mut rng = Pcg32::seeded(102);
        let mut env = SchedEnv::new(ActionSpace::standard(), 30.0,
                                    PlatformSpec::xavier_nx());
        env.episode_len = 48;
        let cfg = SacConfig { warmup: 64, batch_size: 32, ..Default::default() };
        let mut agent =
            DiscreteSac::new(STATE_DIM, env.n_actions(), cfg, &mut rng);
        let hist = train_episodes(&mut env, &mut agent, 14, 48, &mut rng);
        let early: f32 = hist[..4].iter().map(|x| x.0).sum::<f32>() / 4.0;
        let late: f32 =
            hist[hist.len() - 4..].iter().map(|x| x.0).sum::<f32>() / 4.0;
        assert!(
            late > early - 5.0,
            "reward collapsed: early {early} late {late}"
        );
        // The trained policy must be usable greedily.
        let s = env.reset(&mut rng);
        let a = agent.act(&s, &mut rng, true);
        assert!(a < env.n_actions());
    }

    #[test]
    fn subset_env_only_sees_subset() {
        let mut rng = Pcg32::seeded(103);
        let mut env = SchedEnv::new(ActionSpace::standard(), 30.0,
                                    PlatformSpec::jetson_nano());
        env.model_subset =
            Some(vec![ModelId::Yolo, ModelId::Res, ModelId::Bert]);
        env.reset(&mut rng);
        for _ in 0..32 {
            let s = env.step(5, &mut rng);
            if s.done {
                break;
            }
        }
        for o in env.engine().metrics.outcomes() {
            assert!(matches!(o.model,
                             ModelId::Yolo | ModelId::Res | ModelId::Bert));
        }
    }
}
