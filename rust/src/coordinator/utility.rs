//! The throughput/latency trade-off utility of paper Eq. (3), and the
//! constrained reward of Eqs. (4)/(6).
//!
//!   U = log( T(b, m_c) / ( L(b, m_c) / (Σⱼ SLOⱼ / m_c) ) )
//!
//! where T is the slot throughput, L the actual latency, and the
//! denominator normalizes latency by the per-instance SLO budget of
//! Eq. (1). The paper notes the ratio lies in (0, 1] for feasible
//! schedules; we clamp it there (a ratio > 1 means the SLO budget was
//! blown, handled by the reward penalty, not the log). The "min U" in
//! Eq. (4) is read as maximize — the reward of Eq. (6) and all reported
//! results maximize utility.

/// Eq. (3). `throughput_rps` > 0, `latency_ms` > 0, `slo_sum_ms` = Σ SLOⱼ
/// over the batch, `m_c` ≥ 1.
pub fn utility(throughput_rps: f64, latency_ms: f64, slo_sum_ms: f64,
               m_c: usize) -> f64 {
    assert!(m_c >= 1);
    if throughput_rps <= 0.0 || latency_ms <= 0.0 || slo_sum_ms <= 0.0 {
        return f64::NEG_INFINITY;
    }
    let budget_ms = slo_sum_ms / m_c as f64; // Eq. (1) slot budget
    let ratio = (latency_ms / budget_ms).clamp(1e-3, 1.0);
    (throughput_rps / ratio).ln()
}

/// Reward shaping around Eq. (6) r = U, adding the Eq. (4) constraints as
/// penalties so the agent *learns* to avoid infeasible actions:
/// * each SLO violation in the slot subtracts `VIOLATION_PENALTY` ×
///   violation fraction;
/// * an OOM rejection subtracts `OOM_PENALTY` (the hard m ≤ M constraint);
/// * an idle slot (no requests) is worth 0.
pub const VIOLATION_PENALTY: f64 = 4.0;
pub const OOM_PENALTY: f64 = 8.0;

/// Slot-level reward.
pub fn reward(utility: f64, violation_frac: f64, oom: bool) -> f64 {
    let mut r = if utility.is_finite() { utility } else { -OOM_PENALTY };
    r -= VIOLATION_PENALTY * violation_frac.clamp(0.0, 1.0);
    if oom {
        r -= OOM_PENALTY;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn higher_throughput_higher_utility() {
        let u1 = utility(10.0, 50.0, 600.0, 2);
        let u2 = utility(20.0, 50.0, 600.0, 2);
        assert!(u2 > u1);
    }

    #[test]
    fn lower_latency_higher_utility() {
        let u_slow = utility(10.0, 250.0, 600.0, 2);
        let u_fast = utility(10.0, 50.0, 600.0, 2);
        assert!(u_fast > u_slow);
    }

    #[test]
    fn ratio_clamped_to_one() {
        // Latency beyond the budget doesn't push U below ln(T) — the
        // violation penalty handles that regime.
        let at_budget = utility(10.0, 300.0, 600.0, 2);
        let over = utility(10.0, 900.0, 600.0, 2);
        assert_eq!(at_budget, over);
        assert!((over - 10f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn more_instances_shrink_budget() {
        // Same latency, more instances ⇒ tighter per-instance budget ⇒
        // larger ratio ⇒ lower utility (concurrency must EARN its keep via
        // throughput).
        let u2 = utility(10.0, 50.0, 600.0, 2);
        let u4 = utility(10.0, 50.0, 600.0, 4);
        assert!(u4 < u2);
    }

    #[test]
    fn degenerate_inputs_are_neg_infinity() {
        assert_eq!(utility(0.0, 10.0, 100.0, 1), f64::NEG_INFINITY);
        assert_eq!(utility(10.0, 0.0, 100.0, 1), f64::NEG_INFINITY);
    }

    #[test]
    fn reward_penalizes_violations_and_oom() {
        let base = reward(2.0, 0.0, false);
        assert_eq!(base, 2.0);
        assert!(reward(2.0, 0.5, false) < base);
        assert!(reward(2.0, 0.0, true) < base);
        assert_eq!(reward(2.0, 0.5, false), 2.0 - 0.5 * VIOLATION_PENALTY);
        assert_eq!(reward(f64::NEG_INFINITY, 0.0, false), -OOM_PENALTY);
    }
}
