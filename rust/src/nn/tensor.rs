//! Row-major f32 matrix with the operations the control-plane NNs need.
//!
//! Not a general tensor library: rank-2 only, sized for batch×feature
//! matrices in the hundreds. The hot operation is `matmul`, written
//! cache-friendly (i-k-j loop order) — see `rust/benches/fig16_overhead.rs`
//! for why scheduler decision latency matters to the paper (Fig. 16).

use crate::util::rng::Pcg32;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Single-row matrix view of a slice (copies).
    pub fn row_vec(data: &[f32]) -> Self {
        Mat { rows: 1, cols: data.len(), data: data.to_vec() }
    }

    /// Kaiming-uniform init, the PyTorch default the paper's SAC uses.
    pub fn kaiming(rows: usize, cols: usize, rng: &mut Pcg32) -> Self {
        let bound = (6.0 / rows as f64).sqrt() as f32;
        let data = (0..rows * cols)
            .map(|_| (rng.f32() * 2.0 - 1.0) * bound)
            .collect();
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// C = A @ B. i-k-j order so the inner loop streams both B and C rows.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {}x{} @ {}x{}",
                   self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// Allocation-free matmul into a caller buffer (hot path: every SAC
    /// forward/backward goes through here). The inner j-loop is written
    /// over exact-length slice pairs so LLVM autovectorizes it; an
    /// explicit `a == 0` skip was measured SLOWER on dense layers than the
    /// vectorized stream (it breaks SIMD), so sparsity from ReLU is NOT
    /// special-cased — see EXPERIMENTS.md §Perf.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows);
        assert_eq!(out.rows, self.rows);
        assert_eq!(out.cols, other.cols);
        out.data.fill(0.0);
        let n = other.cols;
        for i in 0..self.rows {
            let a_row = &self.data[i * self.cols..(i + 1) * self.cols];
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for (k, &a) in a_row.iter().enumerate() {
                let b_row = &other.data[k * n..(k + 1) * n];
                // exact-length zip → no bounds checks → SIMD
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
    }

    /// Reshape in place, reusing the allocation. Contents are
    /// UNSPECIFIED afterwards (stale values may remain) — callers must
    /// overwrite every element, which all `*_into` consumers below do.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Become a copy of `src`, reusing the allocation.
    pub fn copy_from(&mut self, src: &Mat) {
        self.rows = src.rows;
        self.cols = src.cols;
        self.data.clear();
        self.data.extend_from_slice(&src.data);
    }

    /// C = AᵀB without materializing Aᵀ (the `dw = xᵀ·dy` of backprop).
    /// Accumulates over the batch dimension in the same order as
    /// `a.transpose().matmul(b)`, so results are bit-identical to the
    /// allocating path.
    pub fn matmul_tn_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.rows, other.rows, "matmul_tn {}x{} @ {}x{}",
                   self.cols, self.rows, other.rows, other.cols);
        out.reset(self.cols, other.cols);
        out.data.fill(0.0);
        let n = other.cols;
        for i in 0..self.rows {
            let a_row = &self.data[i * self.cols..(i + 1) * self.cols];
            let b_row = &other.data[i * n..(i + 1) * n];
            for (k, &a) in a_row.iter().enumerate() {
                let out_row = &mut out.data[k * n..(k + 1) * n];
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
    }

    /// C = ABᵀ without materializing Bᵀ (the `dx = dy·wᵀ` of backprop).
    /// Each output element is a dot product of two contiguous rows —
    /// prime autovectorization territory. Accumulation order matches
    /// `a.matmul(&b.transpose())` bit-for-bit.
    pub fn matmul_nt_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.cols, "matmul_nt {}x{} @ {}x{}",
                   self.rows, self.cols, other.cols, other.rows);
        out.reset(self.rows, other.rows);
        let k = self.cols;
        for i in 0..self.rows {
            let a_row = &self.data[i * k..(i + 1) * k];
            let out_row = &mut out.data[i * other.rows..(i + 1) * other.rows];
            for (j, o) in out_row.iter_mut().enumerate() {
                let b_row = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&a, &b) in a_row.iter().zip(b_row) {
                    acc += a * b;
                }
                *o = acc;
            }
        }
    }

    /// Aᵀ (copies).
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Elementwise map (copies).
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise map in place (hot path: activations between layers).
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// In-place ReLU.
    pub fn relu_inplace(&mut self) {
        self.map_inplace(|v| v.max(0.0))
    }

    /// In-place ReLU gradient gate: zero `self` wherever the post-ReLU
    /// activation `act` was clipped. Replaces the seed's mask-`map` +
    /// `hadamard` pair (two full-matrix allocations per layer per
    /// backward pass) with a single fused sweep; values are identical
    /// (kept entries are untouched rather than multiplied by 1.0).
    pub fn relu_backward_inplace(&mut self, act: &Mat) {
        assert_eq!((self.rows, self.cols), (act.rows, act.cols));
        for (d, &a) in self.data.iter_mut().zip(&act.data) {
            if a <= 0.0 {
                *d = 0.0;
            }
        }
    }

    /// self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Elementwise product (copies).
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| a * b)
                .collect(),
        }
    }

    /// Add a row vector to every row (broadcast bias add).
    pub fn add_row_broadcast(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            for (x, &b) in self.row_mut(r).iter_mut().zip(bias) {
                *x += b;
            }
        }
    }

    /// Column sums into a reused buffer (gradient of a broadcast bias).
    pub fn col_sums_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.cols, 0.0);
        for r in 0..self.rows {
            for (o, &x) in out.iter_mut().zip(self.row(r)) {
                *o += x;
            }
        }
    }

    /// Column sums (gradient of a broadcast bias).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.col_sums_into(&mut out);
        out
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

/// Row-wise softmax into a reused buffer, numerically stabilized.
pub fn softmax_rows_into(m: &Mat, out: &mut Mat) {
    out.copy_from(m);
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (*x - max).exp();
            sum += *x;
        }
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
}

/// Row-wise softmax, numerically stabilized.
pub fn softmax_rows(m: &Mat) -> Mat {
    let mut out = Mat::zeros(0, 0);
    softmax_rows_into(m, &mut out);
    out
}

/// Row-wise log-softmax into a reused buffer.
pub fn log_softmax_rows_into(m: &Mat, out: &mut Mat) {
    out.copy_from(m);
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logsum = row.iter().map(|x| (x - max).exp()).sum::<f32>().ln() + max;
        for x in row.iter_mut() {
            *x -= logsum;
        }
    }
}

/// Row-wise log-softmax.
pub fn log_softmax_rows(m: &Mat) -> Mat {
    let mut out = Mat::zeros(0, 0);
    log_softmax_rows_into(m, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_round_trip() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(2, 1), 6.0);
    }

    #[test]
    fn broadcast_and_col_sums_are_adjoint() {
        // The forward bias add broadcasts; its gradient is col_sums.
        let mut a = Mat::zeros(3, 2);
        a.add_row_broadcast(&[1.0, 2.0]);
        assert_eq!(a.col_sums(), vec![3.0, 6.0]);
    }

    #[test]
    fn softmax_rows_normalizes() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 1000., 1000., 1000.]);
        let s = softmax_rows(&m);
        for r in 0..2 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // stable under large inputs
        assert!((s.at(1, 0) - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn log_softmax_consistent_with_softmax() {
        let m = Mat::from_vec(1, 4, vec![0.1, -2.0, 3.0, 0.7]);
        let s = softmax_rows(&m);
        let ls = log_softmax_rows(&m);
        for c in 0..4 {
            assert!((s.at(0, c).ln() - ls.at(0, c)).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_into_matches_matmul() {
        let mut rng = Pcg32::seeded(5);
        let a = Mat::kaiming(7, 11, &mut rng);
        let b = Mat::kaiming(11, 3, &mut rng);
        let mut out = Mat::zeros(7, 3);
        a.matmul_into(&b, &mut out);
        assert_eq!(out, a.matmul(&b));
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        Mat::zeros(2, 3).matmul(&Mat::zeros(4, 2));
    }

    #[test]
    fn matmul_tn_matches_transpose_matmul() {
        let mut rng = Pcg32::seeded(6);
        let x = Mat::kaiming(9, 5, &mut rng);
        let dy = Mat::kaiming(9, 4, &mut rng);
        let mut out = Mat::zeros(0, 0);
        x.matmul_tn_into(&dy, &mut out);
        assert_eq!(out, x.transpose().matmul(&dy));
        // Reuse with a different shape.
        let x2 = Mat::kaiming(3, 7, &mut rng);
        let dy2 = Mat::kaiming(3, 2, &mut rng);
        x2.matmul_tn_into(&dy2, &mut out);
        assert_eq!(out, x2.transpose().matmul(&dy2));
    }

    #[test]
    fn matmul_nt_matches_matmul_transpose() {
        let mut rng = Pcg32::seeded(7);
        let dy = Mat::kaiming(6, 4, &mut rng);
        let w = Mat::kaiming(8, 4, &mut rng);
        let mut out = Mat::zeros(0, 0);
        dy.matmul_nt_into(&w, &mut out);
        assert_eq!(out, dy.matmul(&w.transpose()));
        let dy2 = Mat::kaiming(2, 3, &mut rng);
        let w2 = Mat::kaiming(5, 3, &mut rng);
        dy2.matmul_nt_into(&w2, &mut out);
        assert_eq!(out, dy2.matmul(&w2.transpose()));
    }

    #[test]
    fn inplace_ops_match_allocating_ops() {
        let mut rng = Pcg32::seeded(8);
        let m = Mat::kaiming(5, 6, &mut rng);
        let mut relu = m.clone();
        relu.relu_inplace();
        assert_eq!(relu, m.map(|v| v.max(0.0)));
        let mut gated = Mat::kaiming(5, 6, &mut rng);
        let expect = gated
            .hadamard(&relu.map(|v| if v > 0.0 { 1.0 } else { 0.0 }));
        gated.relu_backward_inplace(&relu);
        assert_eq!(gated, expect);
        let mut sums = Vec::new();
        m.col_sums_into(&mut sums);
        assert_eq!(sums, m.col_sums());
    }

    #[test]
    fn softmax_into_variants_match() {
        let m = Mat::from_vec(2, 3, vec![0.5, -1.0, 2.0, 3.0, 3.0, 3.0]);
        let mut s = Mat::zeros(9, 9); // stale shape must not leak through
        softmax_rows_into(&m, &mut s);
        assert_eq!(s, softmax_rows(&m));
        let mut ls = Mat::zeros(1, 1);
        log_softmax_rows_into(&m, &mut ls);
        assert_eq!(ls, log_softmax_rows(&m));
    }

    #[test]
    fn reset_and_copy_from_reuse_allocation() {
        let mut m = Mat::zeros(4, 4);
        m.reset(2, 3);
        assert_eq!((m.rows(), m.cols()), (2, 3));
        assert_eq!(m.data().len(), 6);
        let src = Mat::from_vec(1, 2, vec![7.0, 8.0]);
        m.copy_from(&src);
        assert_eq!(m, src);
    }
}
