//! Adam optimizer over an [`Mlp`]'s parameters — the paper trains "all
//! networks using the Adam optimizer with a learning rate of 1e-3".

use super::linear::LinearGrad;
use super::mlp::Mlp;
use super::tensor::Mat;

/// Per-layer first/second moment state mirroring the MLP's shapes.
#[derive(Clone)]
struct Moments {
    mw: Mat,
    vw: Mat,
    mb: Vec<f32>,
    vb: Vec<f32>,
}

/// Adam with bias correction (Kingma & Ba 2015 defaults unless overridden).
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: u64,
    state: Vec<Moments>,
}

impl Adam {
    /// Paper settings: lr = 1e-3.
    pub fn new(net: &Mlp, lr: f32) -> Self {
        let state = net
            .layers
            .iter()
            .map(|l| Moments {
                mw: Mat::zeros(l.w.rows(), l.w.cols()),
                vw: Mat::zeros(l.w.rows(), l.w.cols()),
                mb: vec![0.0; l.b.len()],
                vb: vec![0.0; l.b.len()],
            })
            .collect();
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, state }
    }

    /// Apply one descent step from per-layer grads.
    pub fn step(&mut self, net: &mut Mlp, grads: &[LinearGrad]) {
        assert_eq!(grads.len(), net.layers.len());
        self.t += 1;
        let t = self.t as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        for ((layer, g), m) in
            net.layers.iter_mut().zip(grads).zip(&mut self.state)
        {
            for i in 0..layer.w.data().len() {
                let grad = g.dw.data()[i];
                let mw = &mut m.mw.data_mut()[i];
                *mw = self.beta1 * *mw + (1.0 - self.beta1) * grad;
                let vw = &mut m.vw.data_mut()[i];
                *vw = self.beta2 * *vw + (1.0 - self.beta2) * grad * grad;
                let mhat = *mw / bc1;
                let vhat = *vw / bc2;
                layer.w.data_mut()[i] -=
                    self.lr * mhat / (vhat.sqrt() + self.eps);
            }
            for i in 0..layer.b.len() {
                let grad = g.db[i];
                m.mb[i] = self.beta1 * m.mb[i] + (1.0 - self.beta1) * grad;
                m.vb[i] =
                    self.beta2 * m.vb[i] + (1.0 - self.beta2) * grad * grad;
                let mhat = m.mb[i] / bc1;
                let vhat = m.vb[i] / bc2;
                layer.b[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }
}

/// Scalar Adam for single parameters (the SAC temperature log α).
#[derive(Clone, Debug)]
pub struct ScalarAdam {
    pub lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: u64,
    m: f32,
    v: f32,
}

impl ScalarAdam {
    pub fn new(lr: f32) -> Self {
        ScalarAdam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: 0.0, v: 0.0 }
    }

    /// One step; returns the parameter delta to apply.
    pub fn step(&mut self, grad: f32) -> f32 {
        self.t += 1;
        self.m = self.beta1 * self.m + (1.0 - self.beta1) * grad;
        self.v = self.beta2 * self.v + (1.0 - self.beta2) * grad * grad;
        let mhat = self.m / (1.0 - self.beta1.powf(self.t as f32));
        let vhat = self.v / (1.0 - self.beta2.powf(self.t as f32));
        -self.lr * mhat / (vhat.sqrt() + self.eps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::tensor::Mat;
    use crate::util::rng::Pcg32;

    /// Adam must drive a small regression problem to near-zero loss.
    #[test]
    fn fits_linear_function() {
        let mut rng = Pcg32::seeded(31);
        let mut net = Mlp::new(&[2, 16, 1], &mut rng);
        let mut opt = Adam::new(&net, 1e-2);
        let xs: Vec<[f32; 2]> =
            (0..64).map(|_| [rng.f32() * 2.0 - 1.0, rng.f32() * 2.0 - 1.0]).collect();
        let target = |x: &[f32; 2]| 3.0 * x[0] - 2.0 * x[1] + 0.5;
        let mut last = f32::INFINITY;
        for epoch in 0..400 {
            let x = Mat::from_vec(64, 2, xs.iter().flatten().cloned().collect());
            let y: Vec<f32> = xs.iter().map(target).collect();
            let cache = net.forward_cache(&x);
            let out = cache.output();
            // MSE gradient: 2 (ŷ − y) / n
            let mut d = Mat::zeros(64, 1);
            let mut loss = 0.0;
            for i in 0..64 {
                let e = out.at(i, 0) - y[i];
                loss += e * e / 64.0;
                *d.at_mut(i, 0) = 2.0 * e / 64.0;
            }
            let grads = net.backward(&cache, &d);
            opt.step(&mut net, &grads);
            if epoch % 100 == 0 {
                last = loss;
            }
        }
        assert!(last < 0.05, "loss did not converge: {last}");
    }

    #[test]
    fn scalar_adam_descends() {
        // Minimize f(x) = (x − 3)² from x = 0.
        let mut x = 0.0f32;
        let mut opt = ScalarAdam::new(0.05);
        for _ in 0..2000 {
            let grad = 2.0 * (x - 3.0);
            x += opt.step(grad);
        }
        assert!((x - 3.0).abs() < 0.05, "x = {x}");
    }
}
