//! Fully-connected layer with explicit forward/backward.

use super::tensor::Mat;
use crate::util::rng::Pcg32;

/// y = x @ w + b, with cached-input backward.
#[derive(Clone, Debug)]
pub struct Linear {
    /// (in_dim, out_dim)
    pub w: Mat,
    /// (out_dim,)
    pub b: Vec<f32>,
}

/// Gradients for one layer, same shapes as the parameters.
#[derive(Clone, Debug)]
pub struct LinearGrad {
    pub dw: Mat,
    pub db: Vec<f32>,
}

impl Linear {
    pub fn new(in_dim: usize, out_dim: usize, rng: &mut Pcg32) -> Self {
        Linear { w: Mat::kaiming(in_dim, out_dim, rng), b: vec![0.0; out_dim] }
    }

    pub fn in_dim(&self) -> usize {
        self.w.rows()
    }

    pub fn out_dim(&self) -> usize {
        self.w.cols()
    }

    /// Forward into a reused output buffer: x (batch, in) → (batch, out).
    pub fn forward_into(&self, x: &Mat, out: &mut Mat) {
        out.reset(x.rows(), self.w.cols());
        x.matmul_into(&self.w, out);
        out.add_row_broadcast(&self.b);
    }

    /// Forward: x (batch, in) → (batch, out).
    pub fn forward(&self, x: &Mat) -> Mat {
        let mut y = Mat::zeros(0, 0);
        self.forward_into(x, &mut y);
        y
    }

    /// Backward into reused buffers — no transposes are materialized and
    /// no gradient matrices are allocated in steady state. Values are
    /// bit-identical to [`Linear::backward`].
    pub fn backward_into(&self, x: &Mat, dy: &Mat, grad: &mut LinearGrad,
                         dx: &mut Mat) {
        x.matmul_tn_into(dy, &mut grad.dw);
        dy.col_sums_into(&mut grad.db);
        dy.matmul_nt_into(&self.w, dx);
    }

    /// Backward given the layer input and upstream gradient.
    /// Returns (grad wrt input, parameter grads).
    pub fn backward(&self, x: &Mat, dy: &Mat) -> (Mat, LinearGrad) {
        let mut grad = LinearGrad { dw: Mat::zeros(0, 0), db: Vec::new() };
        let mut dx = Mat::zeros(0, 0);
        self.backward_into(x, dy, &mut grad, &mut dx);
        (dx, grad)
    }

    /// Polyak averaging toward `src`: θ ← τ·θ_src + (1−τ)·θ (SAC target nets).
    pub fn soft_update_from(&mut self, src: &Linear, tau: f32) {
        for (t, &s) in self.w.data_mut().iter_mut().zip(src.w.data()) {
            *t = tau * s + (1.0 - tau) * *t;
        }
        for (t, &s) in self.b.iter_mut().zip(&src.b) {
            *t = tau * s + (1.0 - tau) * *t;
        }
    }

    /// Number of scalar parameters.
    pub fn param_count(&self) -> usize {
        self.w.rows() * self.w.cols() + self.b.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_known_values() {
        let mut l = Linear::new(2, 2, &mut Pcg32::seeded(0));
        l.w = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        l.b = vec![0.5, -0.5];
        let y = l.forward(&Mat::from_vec(1, 2, vec![1., 1.]));
        assert_eq!(y.data(), &[4.5, 5.5]);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Pcg32::seeded(9);
        let l = Linear::new(4, 3, &mut rng);
        let x = Mat::kaiming(5, 4, &mut rng);
        // Loss = sum(y) so dy = ones; check dW numerically.
        let loss = |layer: &Linear| -> f32 {
            layer.forward(&x).data().iter().sum()
        };
        let dy = Mat::from_vec(5, 3, vec![1.0; 15]);
        let (_, grad) = l.backward(&x, &dy);
        let eps = 1e-3f32;
        for idx in [0usize, 5, 11] {
            let mut lp = l.clone();
            lp.w.data_mut()[idx] += eps;
            let mut lm = l.clone();
            lm.w.data_mut()[idx] -= eps;
            let num = (loss(&lp) - loss(&lm)) / (2.0 * eps);
            assert!(
                (num - grad.dw.data()[idx]).abs() < 1e-2,
                "dW[{idx}]: numeric {num} vs analytic {}",
                grad.dw.data()[idx]
            );
        }
        // bias grad: column sums of dy = batch size.
        assert!(grad.db.iter().all(|&g| (g - 5.0).abs() < 1e-5));
    }

    #[test]
    fn input_gradient_matches_finite_differences() {
        let mut rng = Pcg32::seeded(10);
        let l = Linear::new(3, 2, &mut rng);
        let x = Mat::kaiming(2, 3, &mut rng);
        let dy = Mat::from_vec(2, 2, vec![1.0; 4]);
        let (dx, _) = l.backward(&x, &dy);
        let eps = 1e-3f32;
        for idx in 0..6 {
            let mut xp = x.clone();
            xp.data_mut()[idx] += eps;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= eps;
            let f = |m: &Mat| l.forward(m).data().iter().sum::<f32>();
            let num = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!((num - dx.data()[idx]).abs() < 1e-2);
        }
    }

    #[test]
    fn into_paths_match_allocating_paths() {
        let mut rng = Pcg32::seeded(12);
        let l = Linear::new(4, 3, &mut rng);
        let x = Mat::kaiming(5, 4, &mut rng);
        let dy = Mat::kaiming(5, 3, &mut rng);
        let mut y = Mat::zeros(0, 0);
        let mut grad = LinearGrad { dw: Mat::zeros(0, 0), db: Vec::new() };
        let mut dx = Mat::zeros(0, 0);
        // Run twice through the same buffers: reuse must not contaminate.
        for _ in 0..2 {
            l.forward_into(&x, &mut y);
            assert_eq!(y, l.forward(&x));
            l.backward_into(&x, &dy, &mut grad, &mut dx);
            let (dx_ref, grad_ref) = l.backward(&x, &dy);
            assert_eq!(dx, dx_ref);
            assert_eq!(grad.dw, grad_ref.dw);
            assert_eq!(grad.db, grad_ref.db);
        }
    }

    #[test]
    fn soft_update_moves_toward_source() {
        let mut rng = Pcg32::seeded(11);
        let src = Linear::new(3, 3, &mut rng);
        let mut tgt = Linear::new(3, 3, &mut rng);
        let before = tgt.w.data()[0];
        tgt.soft_update_from(&src, 0.5);
        let expect = 0.5 * src.w.data()[0] + 0.5 * before;
        assert!((tgt.w.data()[0] - expect).abs() < 1e-6);
        // tau = 1 copies exactly
        tgt.soft_update_from(&src, 1.0);
        assert_eq!(tgt.w.data(), src.w.data());
    }
}
