//! Multi-layer perceptron with ReLU hidden activations and explicit
//! backprop — the network shape the paper trains everywhere: "each network
//! has a two-layer ReLU neural network with 128 and 64 hidden units"
//! (§V-A Training Details).

use super::linear::{Linear, LinearGrad};
use super::tensor::Mat;
use crate::util::rng::Pcg32;

/// MLP: linear → ReLU → … → linear (identity output head).
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Linear>,
}

/// Cached per-layer inputs (pre-layer activations) for backward.
///
/// Reusable: allocate once (`ForwardCache::new`) and refill every
/// iteration with [`Mlp::forward_cache_into`] — after the first pass all
/// the activation matrices are recycled, so the SAC update loop (which
/// runs this thousands of times per training run) stops cloning every
/// activation the way the seed did.
pub struct ForwardCache {
    /// inputs[i] is the input fed to layers[i]; plus the final output last.
    inputs: Vec<Mat>,
    output: Mat,
}

impl Default for ForwardCache {
    fn default() -> Self {
        ForwardCache { inputs: Vec::new(), output: Mat::zeros(0, 0) }
    }
}

impl ForwardCache {
    pub fn new() -> Self {
        ForwardCache::default()
    }

    pub fn output(&self) -> &Mat {
        &self.output
    }
}

/// Reused intermediates for [`Mlp::backward_into`]: the upstream
/// gradient and the layer-input gradient ping-pong between these two
/// buffers as backprop walks the layers.
pub struct BackwardScratch {
    dy: Mat,
    dx: Mat,
}

impl Default for BackwardScratch {
    fn default() -> Self {
        BackwardScratch { dy: Mat::zeros(0, 0), dx: Mat::zeros(0, 0) }
    }
}

impl BackwardScratch {
    pub fn new() -> Self {
        BackwardScratch::default()
    }
}

/// Gradients for every layer.
pub type MlpGrad = Vec<LinearGrad>;

impl Mlp {
    /// Build from layer sizes, e.g. `[in, 128, 64, out]` for the paper's
    /// two-hidden-layer nets.
    pub fn new(sizes: &[usize], rng: &mut Pcg32) -> Self {
        assert!(sizes.len() >= 2, "need at least in/out sizes");
        let layers = sizes
            .windows(2)
            .map(|w| Linear::new(w[0], w[1], rng))
            .collect();
        Mlp { layers }
    }

    pub fn in_dim(&self) -> usize {
        self.layers.first().unwrap().in_dim()
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().unwrap().out_dim()
    }

    /// Forward pass into reused buffers. `out` receives the network
    /// output; `tmp` is ping-pong scratch for the hidden activations.
    /// Zero allocations once the buffers have grown to the layer widths.
    pub fn forward_into(&self, x: &Mat, out: &mut Mat, tmp: &mut Mat) {
        let last = self.layers.len() - 1;
        if last == 0 {
            self.layers[0].forward_into(x, out);
            return;
        }
        self.layers[0].forward_into(x, tmp);
        tmp.relu_inplace();
        for i in 1..last {
            self.layers[i].forward_into(tmp, out);
            out.relu_inplace();
            std::mem::swap(tmp, out);
        }
        self.layers[last].forward_into(tmp, out);
    }

    /// Plain forward pass.
    pub fn forward(&self, x: &Mat) -> Mat {
        let mut out = Mat::zeros(0, 0);
        let mut tmp = Mat::zeros(0, 0);
        self.forward_into(x, &mut out, &mut tmp);
        out
    }

    /// Forward pass retaining per-layer inputs for backward, writing into
    /// a reused cache: all activation matrices are recycled across calls.
    pub fn forward_cache_into(&self, x: &Mat, cache: &mut ForwardCache) {
        let n = self.layers.len();
        while cache.inputs.len() < n {
            cache.inputs.push(Mat::zeros(0, 0));
        }
        cache.inputs.truncate(n);
        cache.inputs[0].copy_from(x);
        let last = n - 1;
        for i in 0..n {
            if i < last {
                // inputs[i] feeds layer i; its post-ReLU output is
                // inputs[i+1]. split_at_mut to borrow both.
                let (head, tail) = cache.inputs.split_at_mut(i + 1);
                let dst = &mut tail[0];
                self.layers[i].forward_into(&head[i], dst);
                dst.relu_inplace();
            } else {
                self.layers[i].forward_into(&cache.inputs[i], &mut cache.output);
            }
        }
    }

    /// Forward pass retaining per-layer inputs for backward.
    pub fn forward_cache(&self, x: &Mat) -> ForwardCache {
        let mut cache = ForwardCache::new();
        self.forward_cache_into(x, &mut cache);
        cache
    }

    /// Backprop `d_out` through the cached pass into reused gradient and
    /// scratch buffers. The ReLU gate runs in place on the upstream
    /// gradient (the seed allocated a mask matrix + a hadamard product
    /// per layer) and the per-layer `dw`/`dx` matmuls write into recycled
    /// matrices. Values are bit-identical to [`Mlp::backward`].
    pub fn backward_into(&self, cache: &ForwardCache, d_out: &Mat,
                         grads: &mut MlpGrad, scratch: &mut BackwardScratch) {
        let n = self.layers.len();
        while grads.len() < n {
            grads.push(LinearGrad { dw: Mat::zeros(0, 0), db: Vec::new() });
        }
        grads.truncate(n);
        let last = n - 1;
        scratch.dy.copy_from(d_out);
        for i in (0..n).rev() {
            if i != last {
                // Gradient through the ReLU that followed layer i:
                // zero where the *post-layer* activation was clipped. That
                // activation is exactly inputs[i+1].
                scratch.dy.relu_backward_inplace(&cache.inputs[i + 1]);
            }
            self.layers[i].backward_into(&cache.inputs[i], &scratch.dy,
                                         &mut grads[i], &mut scratch.dx);
            std::mem::swap(&mut scratch.dy, &mut scratch.dx);
        }
    }

    /// Backprop `d_out` (gradient w.r.t. the network output) through the
    /// cached pass; returns per-layer parameter grads.
    pub fn backward(&self, cache: &ForwardCache, d_out: &Mat) -> MlpGrad {
        let mut grads = Vec::new();
        let mut scratch = BackwardScratch::new();
        self.backward_into(cache, d_out, &mut grads, &mut scratch);
        grads
    }

    /// Polyak-average every layer toward `src` (SAC target networks).
    pub fn soft_update_from(&mut self, src: &Mlp, tau: f32) {
        assert_eq!(self.layers.len(), src.layers.len());
        for (t, s) in self.layers.iter_mut().zip(&src.layers) {
            t.soft_update_from(s, tau);
        }
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Serialize weights to JSON (policy checkpoints: the paper trains
    /// offline and deploys the trained scheduler online).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{arr, num, obj};
        obj(vec![(
            "layers",
            arr(self.layers.iter().map(|l| {
                obj(vec![
                    ("in", num(l.w.rows() as f64)),
                    ("out", num(l.w.cols() as f64)),
                    ("w", arr(l.w.data().iter().map(|&x| num(x as f64)))),
                    ("b", arr(l.b.iter().map(|&x| num(x as f64)))),
                ])
            })),
        )])
    }

    /// Deserialize from [`Mlp::to_json`] output.
    pub fn from_json(v: &crate::util::json::Json) -> Result<Mlp, String> {
        use crate::util::json::Json;
        let layers_json =
            v.get("layers").and_then(Json::as_arr).ok_or("missing layers")?;
        let mut layers = Vec::with_capacity(layers_json.len());
        for lj in layers_json {
            let rows = lj.get("in").and_then(Json::as_usize).ok_or("in")?;
            let cols = lj.get("out").and_then(Json::as_usize).ok_or("out")?;
            let w: Vec<f32> = lj
                .get("w")
                .and_then(Json::as_arr)
                .ok_or("w")?
                .iter()
                .filter_map(|x| x.as_f64().map(|f| f as f32))
                .collect();
            let b: Vec<f32> = lj
                .get("b")
                .and_then(Json::as_arr)
                .ok_or("b")?
                .iter()
                .filter_map(|x| x.as_f64().map(|f| f as f32))
                .collect();
            if w.len() != rows * cols || b.len() != cols {
                return Err("layer shape mismatch".into());
            }
            layers.push(Linear { w: Mat::from_vec(rows, cols, w), b });
        }
        if layers.is_empty() {
            return Err("empty network".into());
        }
        Ok(Mlp { layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn num_grad(mlp: &Mlp, x: &Mat, layer: usize, idx: usize, eps: f32) -> f32 {
        // Loss = sum of outputs.
        let mut p = mlp.clone();
        p.layers[layer].w.data_mut()[idx] += eps;
        let mut m = mlp.clone();
        m.layers[layer].w.data_mut()[idx] -= eps;
        let f = |net: &Mlp| net.forward(x).data().iter().sum::<f32>();
        (f(&p) - f(&m)) / (2.0 * eps)
    }

    #[test]
    fn gradient_check_all_layers() {
        let mut rng = Pcg32::seeded(21);
        let mlp = Mlp::new(&[5, 8, 6, 3], &mut rng);
        let x = Mat::kaiming(4, 5, &mut rng);
        let cache = mlp.forward_cache(&x);
        let ones = Mat::from_vec(4, 3, vec![1.0; 12]);
        let grads = mlp.backward(&cache, &ones);
        for layer in 0..3 {
            for idx in [0usize, 3, 7] {
                if idx >= grads[layer].dw.data().len() {
                    continue;
                }
                let num = num_grad(&mlp, &x, layer, idx, 1e-2);
                let ana = grads[layer].dw.data()[idx];
                assert!(
                    (num - ana).abs() < 3e-2 + 0.05 * ana.abs(),
                    "layer {layer} idx {idx}: numeric {num} vs analytic {ana}"
                );
            }
        }
    }

    #[test]
    fn forward_cache_output_matches_forward() {
        let mut rng = Pcg32::seeded(22);
        let mlp = Mlp::new(&[4, 128, 64, 2], &mut rng);
        let x = Mat::kaiming(3, 4, &mut rng);
        assert_eq!(mlp.forward(&x), *mlp.forward_cache(&x).output());
    }

    #[test]
    fn reused_buffers_match_allocating_paths() {
        let mut rng = Pcg32::seeded(26);
        let mlp = Mlp::new(&[6, 16, 8, 3], &mut rng);
        let mut cache = ForwardCache::new();
        let mut grads: MlpGrad = Vec::new();
        let mut scratch = BackwardScratch::new();
        let (mut out, mut tmp) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        // Vary the batch size across iterations so shape resets are
        // exercised along with allocation reuse.
        for batch in [4usize, 7, 2, 7] {
            let x = Mat::kaiming(batch, 6, &mut rng);
            mlp.forward_into(&x, &mut out, &mut tmp);
            assert_eq!(out, mlp.forward(&x));
            mlp.forward_cache_into(&x, &mut cache);
            let fresh = mlp.forward_cache(&x);
            assert_eq!(cache.output(), fresh.output());
            assert_eq!(*cache.output(), mlp.forward(&x));
            let d = Mat::kaiming(batch, 3, &mut rng);
            mlp.backward_into(&cache, &d, &mut grads, &mut scratch);
            let fresh_grads = mlp.backward(&fresh, &d);
            assert_eq!(grads.len(), fresh_grads.len());
            for (a, b) in grads.iter().zip(&fresh_grads) {
                assert_eq!(a.dw, b.dw);
                assert_eq!(a.db, b.db);
            }
        }
    }

    #[test]
    fn paper_network_shape() {
        let mut rng = Pcg32::seeded(23);
        let mlp = Mlp::new(&[10, 128, 64, 24], &mut rng);
        assert_eq!(mlp.in_dim(), 10);
        assert_eq!(mlp.out_dim(), 24);
        assert_eq!(
            mlp.param_count(),
            10 * 128 + 128 + 128 * 64 + 64 + 64 * 24 + 24
        );
    }

    #[test]
    fn json_round_trip_preserves_outputs() {
        let mut rng = Pcg32::seeded(25);
        let mlp = Mlp::new(&[4, 8, 2], &mut rng);
        let text = mlp.to_json().to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let back = Mlp::from_json(&parsed).unwrap();
        let x = Mat::kaiming(3, 4, &mut rng);
        let a = mlp.forward(&x);
        let b = back.forward(&x);
        for (p, q) in a.data().iter().zip(b.data()) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn from_json_rejects_garbage() {
        let v = crate::util::json::parse("{}").unwrap();
        assert!(Mlp::from_json(&v).is_err());
    }

    #[test]
    fn relu_kills_gradient_for_dead_units() {
        let mut rng = Pcg32::seeded(24);
        let mut mlp = Mlp::new(&[2, 2, 1], &mut rng);
        // Force hidden unit 0 dead (large negative bias).
        mlp.layers[0].b = vec![-1e6, 0.0];
        let x = Mat::from_vec(1, 2, vec![1.0, 1.0]);
        let cache = mlp.forward_cache(&x);
        let grads = mlp.backward(&cache, &Mat::from_vec(1, 1, vec![1.0]));
        // Weights into the dead unit get zero gradient.
        assert_eq!(grads[0].dw.at(0, 0), 0.0);
        assert_eq!(grads[0].dw.at(1, 0), 0.0);
        assert_eq!(grads[0].db[0], 0.0);
    }
}
