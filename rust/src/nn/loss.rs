//! Loss functions and their gradients for the control-plane networks.

use super::tensor::Mat;

/// Mean-squared error over all elements; returns (loss, d_loss/d_pred).
pub fn mse(pred: &Mat, target: &Mat) -> (f32, Mat) {
    assert_eq!((pred.rows(), pred.cols()), (target.rows(), target.cols()));
    let n = (pred.rows() * pred.cols()) as f32;
    let mut grad = Mat::zeros(pred.rows(), pred.cols());
    let mut loss = 0.0;
    for i in 0..pred.data().len() {
        let e = pred.data()[i] - target.data()[i];
        loss += e * e;
        grad.data_mut()[i] = 2.0 * e / n;
    }
    (loss / n, grad)
}

/// Huber (smooth-L1) loss, the standard stabilizer for Q-regression in
/// DDQN; returns (loss, gradient).
pub fn huber(pred: &Mat, target: &Mat, delta: f32) -> (f32, Mat) {
    assert_eq!((pred.rows(), pred.cols()), (target.rows(), target.cols()));
    let n = (pred.rows() * pred.cols()) as f32;
    let mut grad = Mat::zeros(pred.rows(), pred.cols());
    let mut loss = 0.0;
    for i in 0..pred.data().len() {
        let e = pred.data()[i] - target.data()[i];
        if e.abs() <= delta {
            loss += 0.5 * e * e;
            grad.data_mut()[i] = e / n;
        } else {
            loss += delta * (e.abs() - 0.5 * delta);
            grad.data_mut()[i] = delta * e.signum() / n;
        }
    }
    (loss / n, grad)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_zero_at_match() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let (l, g) = mse(&a, &a);
        assert_eq!(l, 0.0);
        assert!(g.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn mse_gradient_finite_difference() {
        let p = Mat::from_vec(1, 3, vec![1.0, -2.0, 0.5]);
        let t = Mat::from_vec(1, 3, vec![0.0, 1.0, 0.5]);
        let (_, g) = mse(&p, &t);
        let eps = 1e-3;
        for i in 0..3 {
            let mut pp = p.clone();
            pp.data_mut()[i] += eps;
            let mut pm = p.clone();
            pm.data_mut()[i] -= eps;
            let num = (mse(&pp, &t).0 - mse(&pm, &t).0) / (2.0 * eps);
            assert!((num - g.data()[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn huber_is_quadratic_then_linear() {
        let t = Mat::from_vec(1, 1, vec![0.0]);
        let small = Mat::from_vec(1, 1, vec![0.5]);
        let large = Mat::from_vec(1, 1, vec![10.0]);
        let (ls, gs) = huber(&small, &t, 1.0);
        let (ll, gl) = huber(&large, &t, 1.0);
        assert!((ls - 0.125).abs() < 1e-6);
        assert!((gs.data()[0] - 0.5).abs() < 1e-6);
        assert!((ll - 9.5).abs() < 1e-6);
        assert!((gl.data()[0] - 1.0).abs() < 1e-6); // clipped gradient
    }
}
