//! From-scratch neural-network substrate for the online components.
//!
//! BCEdge runs TWO kinds of neural networks:
//!
//! 1. the served DNN zoo — authored in JAX/Pallas, AOT-compiled, executed
//!    through PJRT (`crate::runtime`), never touched here;
//! 2. the *control-plane* networks — the discrete-SAC scheduler's
//!    actor/critics (paper Eqs. 5–12) and the SLO-aware interference
//!    predictor (§IV-F). These are small 2-layer MLPs (128/64 hidden
//!    units per the paper's Training Details) that must train online
//!    inside the rust coordinator, so they are implemented here with
//!    explicit forward/backward passes and Adam — gradient-checked
//!    against finite differences in the test suite.

pub mod adam;
pub mod linear;
pub mod loss;
pub mod mlp;
pub mod tensor;

pub use adam::Adam;
pub use linear::Linear;
pub use mlp::Mlp;
pub use tensor::Mat;
