//! NN-based interference predictor (paper §IV-F, Fig. 5).
//!
//! "A lightweight two-layer neural network with negligible overhead …
//! utilizes the currently available computing resources (memory, CPU and
//! GPU) and the number of concurrent models learned by the scheduler as
//! the input", trained online against the actual latency reported by the
//! performance profiler. The regression target here is the latency
//! *inflation factor* (measured / isolated), which transfers across
//! models and batch sizes.

use crate::nn::adam::Adam;
use crate::nn::loss::mse;
use crate::nn::mlp::{BackwardScratch, ForwardCache, MlpGrad};
use crate::nn::tensor::Mat;
use crate::nn::Mlp;
use crate::util::rng::Pcg32;
use std::cell::RefCell;

/// Input features (paper Fig. 5): available memory, compute occupancy,
/// active instances, requested concurrency, normalized batch.
pub const FEATURES: usize = 5;

/// Rolling window of observed/predicted inflation ratios backing the
/// p95 dispersion factor (quantile-aware admission prices tail risk as
/// `prediction × dispersion_p95`).
pub const DISPERSION_WINDOW: usize = 128;

/// Refresh cadence for the cached dispersion quantile: recomputing a
/// 128-element sort on every observation would tax the per-slot
/// accounting path for no accuracy gain, so the quantile is amortized.
const DISPERSION_REFRESH: usize = 32;

/// One training sample collected by the profiler.
#[derive(Clone, Copy, Debug)]
pub struct PredictorSample {
    pub memory_pressure: f64,
    pub compute_demand: f64,
    pub active_instances: usize,
    pub concurrency: usize,
    pub batch: usize,
    /// Ground truth: measured latency / isolated latency (≥ 1).
    pub inflation: f64,
}

impl PredictorSample {
    pub fn features(&self) -> [f32; FEATURES] {
        [
            self.memory_pressure as f32,
            (self.compute_demand / 8.0) as f32,
            self.active_instances as f32 / 8.0,
            self.concurrency as f32 / 8.0,
            (self.batch as f32 / 128.0).min(1.0),
        ]
    }
}

/// Online-trained interference predictor.
pub struct InterferencePredictor {
    net: Mlp,
    opt: Adam,
    /// Ring buffer of training samples: `next` is the overwrite cursor
    /// once full. The seed used `Vec::remove(0)`, an O(capacity) memmove
    /// on EVERY observed instance-batch once warm — a hot-path cost that
    /// grew with the buffer, not the work. `train_step` samples indices
    /// uniformly, so the retained MULTISET matches the seed exactly;
    /// element order inside the vec does not (the ring rotates in place),
    /// which makes minibatch draws equal only in distribution — runs that
    /// wrap the ring (> capacity observations) are no longer bit-identical
    /// to the seed, only statistically equivalent.
    buf: Vec<PredictorSample>,
    next: usize,
    capacity: usize,
    pub batch_size: usize,
    trained_steps: usize,
    /// Ring of observed/predicted inflation ratios (the multiplicative
    /// residuals), windowed to [`DISPERSION_WINDOW`]: how far reality has
    /// recently strayed above the net's point estimate.
    resid: Vec<f32>,
    resid_next: usize,
    resid_seen: usize,
    /// Cached p95 of `resid` (NaN until the first refresh); reused sort
    /// scratch keeps the refresh allocation-free once warm.
    q95: f64,
    resid_scratch: Vec<f32>,
    /// Reused forward buffers for [`InterferencePredictor::predict`].
    /// The engine probes the predictor up to 8× per model per round
    /// through `&self`, so the scratch sits behind a `RefCell` —
    /// single-threaded interior mutability, no lock. The seed allocated a
    /// row matrix plus every hidden activation per probe
    /// ([`InterferencePredictor::predict_alloc`] keeps that path as the
    /// equivalence oracle).
    predict_scratch: RefCell<PredictScratch>,
    /// Reused minibatch + backprop buffers for
    /// [`InterferencePredictor::train_step`] (the seed rebuilt x/y and
    /// every activation/gradient matrix every 4 slots).
    train_x: Mat,
    train_y: Mat,
    train_cache: ForwardCache,
    train_grads: MlpGrad,
    train_scratch: BackwardScratch,
}

struct PredictScratch {
    x: Mat,
    out: Mat,
    tmp: Mat,
}

impl InterferencePredictor {
    /// Paper architecture: two-layer ReLU net (small: 32/16 — "negligible
    /// overhead"), Adam 1e-3.
    pub fn new(rng: &mut Pcg32) -> Self {
        let net = Mlp::new(&[FEATURES, 32, 16, 1], rng);
        let opt = Adam::new(&net, 1e-3);
        InterferencePredictor {
            net,
            opt,
            buf: Vec::new(),
            next: 0,
            capacity: 4096,
            batch_size: 64,
            trained_steps: 0,
            resid: Vec::new(),
            resid_next: 0,
            resid_seen: 0,
            q95: f64::NAN,
            resid_scratch: Vec::new(),
            predict_scratch: RefCell::new(PredictScratch {
                x: Mat::zeros(1, FEATURES),
                out: Mat::zeros(0, 0),
                tmp: Mat::zeros(0, 0),
            }),
            train_x: Mat::zeros(0, 0),
            train_y: Mat::zeros(0, 0),
            train_cache: ForwardCache::new(),
            train_grads: MlpGrad::new(),
            train_scratch: BackwardScratch::new(),
        }
    }

    /// Record a profiled ground-truth sample. O(1) amortized: overwrites
    /// the oldest slot once the ring is full, and folds the sample's
    /// observed/predicted ratio into the dispersion window (the quantile
    /// itself refreshes every [`DISPERSION_REFRESH`] observations).
    pub fn observe(&mut self, s: PredictorSample) {
        let ratio = s.inflation / self.predict(&s);
        if ratio.is_finite() && ratio > 0.0 {
            if self.resid.len() < DISPERSION_WINDOW {
                self.resid.push(ratio as f32);
            } else {
                self.resid[self.resid_next] = ratio as f32;
                self.resid_next = (self.resid_next + 1) % DISPERSION_WINDOW;
            }
            self.resid_seen += 1;
            if self.resid_seen % DISPERSION_REFRESH == 0 {
                self.refresh_dispersion();
            }
        }
        if self.buf.len() < self.capacity {
            self.buf.push(s);
        } else {
            self.buf[self.next] = s;
            self.next = (self.next + 1) % self.capacity;
        }
    }

    /// p95 of the observed/predicted inflation ratios over the last
    /// [`DISPERSION_WINDOW`] ground-truth samples — the multiplicative
    /// factor quantile-aware admission widens predictions by. NaN until
    /// the first refresh (callers treat NaN as "no dispersion data" and
    /// degrade to mean pricing).
    pub fn dispersion_p95(&self) -> f64 {
        self.q95
    }

    fn refresh_dispersion(&mut self) {
        self.resid_scratch.clear();
        self.resid_scratch.extend_from_slice(&self.resid);
        self.resid_scratch
            .sort_by(|a, b| a.partial_cmp(b).expect("ratios are finite"));
        let n = self.resid_scratch.len();
        // Conservative (ceiling) index: with few samples, round toward
        // the tail rather than under-reporting dispersion.
        let idx = ((n - 1) as f64 * 0.95).ceil() as usize;
        self.q95 = self.resid_scratch[idx] as f64;
    }

    pub fn samples(&self) -> usize {
        self.buf.len()
    }

    pub fn trained_steps(&self) -> usize {
        self.trained_steps
    }

    /// Predicted inflation factor for a candidate configuration (≥ 1).
    /// Allocation-free once warm: the probe row and hidden activations
    /// live in the reused scratch. Bit-identical to
    /// [`InterferencePredictor::predict_alloc`] (pinned by test).
    pub fn predict(&self, s: &PredictorSample) -> f64 {
        let mut sc = self.predict_scratch.borrow_mut();
        let sc = &mut *sc;
        sc.x.row_mut(0).copy_from_slice(&s.features());
        self.net.forward_into(&sc.x, &mut sc.out, &mut sc.tmp);
        // Softplus-ish floor: inflation can never be below 1.
        (1.0 + sc.out.at(0, 0).max(0.0)) as f64
    }

    /// The seed's allocating prediction path, kept as the equivalence
    /// oracle for [`InterferencePredictor::predict`] (and as the "before"
    /// side of the hot-path bench).
    pub fn predict_alloc(&self, s: &PredictorSample) -> f64 {
        let x = Mat::row_vec(&s.features());
        (1.0 + self.net.forward(&x).at(0, 0).max(0.0)) as f64
    }

    /// One SGD step on a random minibatch; returns the MSE loss. The
    /// minibatch matrices, activation cache, and gradient buffers are all
    /// reused across calls — bit-identical math to
    /// [`InterferencePredictor::train_step_alloc`].
    pub fn train_step(&mut self, rng: &mut Pcg32) -> f32 {
        if self.buf.len() < self.batch_size {
            return 0.0;
        }
        let n = self.batch_size;
        if self.train_x.rows() != n {
            self.train_x = Mat::zeros(n, FEATURES);
            self.train_y = Mat::zeros(n, 1);
        }
        for i in 0..n {
            let s = &self.buf[rng.below(self.buf.len() as u32) as usize];
            self.train_x.row_mut(i).copy_from_slice(&s.features());
            *self.train_y.at_mut(i, 0) = (s.inflation - 1.0) as f32;
        }
        self.net.forward_cache_into(&self.train_x, &mut self.train_cache);
        // Clamp negative predictions at the loss level too (target ≥ 0).
        let (loss, grad) = mse(self.train_cache.output(), &self.train_y);
        self.net.backward_into(&self.train_cache, &grad,
                               &mut self.train_grads,
                               &mut self.train_scratch);
        self.opt.step(&mut self.net, &self.train_grads);
        self.trained_steps += 1;
        loss
    }

    /// The seed's allocating training step — fresh minibatch matrices and
    /// gradient buffers every call — kept as the equivalence oracle.
    pub fn train_step_alloc(&mut self, rng: &mut Pcg32) -> f32 {
        if self.buf.len() < self.batch_size {
            return 0.0;
        }
        let n = self.batch_size;
        let mut x = Mat::zeros(n, FEATURES);
        let mut y = Mat::zeros(n, 1);
        for i in 0..n {
            let s = &self.buf[rng.below(self.buf.len() as u32) as usize];
            x.row_mut(i).copy_from_slice(&s.features());
            *y.at_mut(i, 0) = (s.inflation - 1.0) as f32;
        }
        let cache = self.net.forward_cache(&x);
        let (loss, grad) = mse(cache.output(), &y);
        let grads = self.net.backward(&cache, &grad);
        self.opt.step(&mut self.net, &grads);
        self.trained_steps += 1;
        loss
    }

    /// Train until converged-ish: `epochs` passes of minibatch steps.
    pub fn fit(&mut self, steps: usize, rng: &mut Pcg32) -> f32 {
        let mut last = 0.0;
        for _ in 0..steps {
            last = self.train_step(rng);
        }
        last
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::interference::{InterferenceModel, SystemLoad};
    use crate::platform::spec::PlatformSpec;

    fn ground_truth_samples(n: usize, rng: &mut Pcg32) -> Vec<PredictorSample> {
        let model = InterferenceModel::default();
        let nx = PlatformSpec::xavier_nx();
        (0..n)
            .map(|_| {
                let load = SystemLoad {
                    active_instances: rng.range(1, 9),
                    compute_demand: rng.f64() * 6.0,
                    memory_pressure: rng.f64(),
                };
                PredictorSample {
                    memory_pressure: load.memory_pressure,
                    compute_demand: load.compute_demand,
                    active_instances: load.active_instances,
                    concurrency: load.active_instances.min(4),
                    batch: 1 << rng.range(0, 8),
                    inflation: model.inflation(&load, &nx),
                }
            })
            .collect()
    }

    #[test]
    fn learns_the_interference_surface() {
        let mut rng = Pcg32::seeded(91);
        let mut pred = InterferencePredictor::new(&mut rng);
        let train = ground_truth_samples(1600, &mut rng); // paper: 1600/400
        let test = ground_truth_samples(400, &mut rng);
        for s in &train {
            pred.observe(*s);
        }
        pred.fit(1500, &mut rng);
        // Relative error on held-out data must be small for most cases
        // (paper: 90 % of cases within ~2.7 %; we require the same order).
        let mut errs: Vec<f64> = test
            .iter()
            .map(|s| (pred.predict(s) - s.inflation).abs() / s.inflation)
            .collect();
        errs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p90 = errs[(0.9 * errs.len() as f64) as usize];
        assert!(p90 < 0.10, "p90 relative error {p90}");
    }

    #[test]
    fn prediction_is_floored_at_one() {
        let mut rng = Pcg32::seeded(92);
        let pred = InterferencePredictor::new(&mut rng);
        let s = PredictorSample {
            memory_pressure: 0.0,
            compute_demand: 0.0,
            active_instances: 0,
            concurrency: 1,
            batch: 1,
            inflation: 1.0,
        };
        assert!(pred.predict(&s) >= 1.0);
    }

    #[test]
    fn train_step_needs_enough_samples() {
        let mut rng = Pcg32::seeded(93);
        let mut pred = InterferencePredictor::new(&mut rng);
        assert_eq!(pred.train_step(&mut rng), 0.0);
    }

    /// The alloc-free probe path must be BIT-IDENTICAL to the seed's
    /// allocating path — the engine's veto decisions (and therefore the
    /// whole outcome stream) hang off these float values.
    #[test]
    fn predict_scratch_matches_alloc_oracle_bitwise() {
        let mut rng = Pcg32::seeded(94);
        let mut pred = InterferencePredictor::new(&mut rng);
        for s in ground_truth_samples(256, &mut rng) {
            pred.observe(s);
        }
        pred.fit(200, &mut rng); // non-trivial weights
        for s in ground_truth_samples(512, &mut rng) {
            let fast = pred.predict(&s);
            let seed = pred.predict_alloc(&s);
            assert!(fast == seed,
                    "predict diverged from alloc oracle: {fast} vs {seed}");
        }
    }

    /// Two predictors with identical init + data + RNG streams, one
    /// trained on the scratch path and one on the seed's allocating path,
    /// must end with identical losses and identical predictions.
    #[test]
    fn train_step_scratch_matches_alloc_oracle() {
        let mut init_a = Pcg32::seeded(95);
        let mut init_b = Pcg32::seeded(95);
        let mut a = InterferencePredictor::new(&mut init_a);
        let mut b = InterferencePredictor::new(&mut init_b);
        let mut data_rng = Pcg32::seeded(96);
        for s in ground_truth_samples(300, &mut data_rng) {
            a.observe(s);
            b.observe(s);
        }
        let mut ra = Pcg32::seeded(97);
        let mut rb = Pcg32::seeded(97);
        for step in 0..50 {
            let la = a.train_step(&mut ra);
            let lb = b.train_step_alloc(&mut rb);
            assert!(la == lb, "loss diverged at step {step}: {la} vs {lb}");
        }
        assert_eq!(a.trained_steps(), b.trained_steps());
        for s in ground_truth_samples(64, &mut data_rng) {
            assert!(a.predict(&s) == b.predict_alloc(&s),
                    "post-training predictions diverged");
        }
    }

    /// Warm-up semantics under a SHIFTING workload: 10k observations
    /// (wrapping the 4096-slot ring more than twice) interleaved with
    /// the engine's amortized training cadence must keep every
    /// prediction finite, floored at 1, and bit-identical to the
    /// allocating oracle — minibatch reuse over a rotating ring must
    /// never feed the optimizer garbage.
    #[test]
    fn warmup_over_shifting_workload_stays_finite_and_bit_identical() {
        let model = InterferenceModel::default();
        let nx = PlatformSpec::xavier_nx();
        let mut rng = Pcg32::seeded(98);
        let mut pred = InterferencePredictor::new(&mut rng);
        for i in 0..10_000usize {
            // The workload drifts: light → heavy → light again, so the
            // ring's resident distribution keeps moving under training.
            let phase = (i as f64 / 10_000.0 * std::f64::consts::TAU).sin();
            let load = SystemLoad {
                active_instances: 1 + ((4.0 + 3.0 * phase) as usize)
                    .min(8),
                compute_demand: (3.0 + 2.5 * phase) * rng.f64(),
                memory_pressure: (0.5 + 0.4 * phase) * rng.f64(),
            };
            pred.observe(PredictorSample {
                memory_pressure: load.memory_pressure,
                compute_demand: load.compute_demand,
                active_instances: load.active_instances,
                concurrency: load.active_instances.min(4),
                batch: 1 << rng.range(0, 8),
                inflation: model.inflation(&load, &nx),
            });
            // The engine trains every 4th accounting slot.
            if i % 4 == 0 {
                let loss = pred.train_step(&mut rng);
                assert!(loss.is_finite(),
                        "training loss went non-finite at observation {i}");
            }
        }
        assert_eq!(pred.samples(), 4096, "ring did not cap at capacity");
        assert!(pred.trained_steps() > 2000);
        // Dispersion tracking stayed sane through the drift.
        let q95 = pred.dispersion_p95();
        assert!(q95.is_finite() && q95 > 0.0, "dispersion p95 {q95}");
        for s in ground_truth_samples(256, &mut rng) {
            let fast = pred.predict(&s);
            assert!(fast.is_finite() && fast >= 1.0,
                    "prediction left its domain: {fast}");
            let seed = pred.predict_alloc(&s);
            assert!(fast == seed,
                    "scratch probe diverged from oracle after wraparound: \
                     {fast} vs {seed}");
        }
    }

    /// Ring wraparound keeps exactly the last `capacity` samples as the
    /// training multiset: after overwriting, a minibatch can only draw
    /// post-wrap samples.
    #[test]
    fn ring_wraparound_retains_only_recent_samples() {
        let mut rng = Pcg32::seeded(99);
        let mut pred = InterferencePredictor::new(&mut rng);
        // Fill past capacity with a marker inflation, then overwrite the
        // whole ring with a different one.
        for _ in 0..4096 {
            pred.observe(PredictorSample {
                memory_pressure: 0.1,
                compute_demand: 1.0,
                active_instances: 1,
                concurrency: 1,
                batch: 8,
                inflation: 7.0,
            });
        }
        for _ in 0..4096 {
            pred.observe(PredictorSample {
                memory_pressure: 0.9,
                compute_demand: 5.0,
                active_instances: 6,
                concurrency: 4,
                batch: 32,
                inflation: 2.0,
            });
        }
        assert_eq!(pred.samples(), 4096);
        // Train long enough that any stale pre-wrap sample in the
        // minibatch stream would drag predictions toward inflation 7.
        pred.fit(400, &mut rng);
        let probe = PredictorSample {
            memory_pressure: 0.9,
            compute_demand: 5.0,
            active_instances: 6,
            concurrency: 4,
            batch: 32,
            inflation: 1.0,
        };
        let p = pred.predict(&probe);
        assert!((p - 2.0).abs() < 0.5,
                "ring retained stale pre-wrap samples: predicted {p}");
    }

    /// The dispersion quantile is clamp-free at the source (callers
    /// clamp): it reflects the ring's actual ratios and refreshes as the
    /// window slides.
    #[test]
    fn dispersion_p95_tracks_recent_ratios() {
        let mut rng = Pcg32::seeded(100);
        let mut pred = InterferencePredictor::new(&mut rng);
        assert!(pred.dispersion_p95().is_nan(), "q95 before any data");
        for s in ground_truth_samples(256, &mut rng) {
            pred.observe(s);
        }
        let q = pred.dispersion_p95();
        assert!(q.is_finite() && q > 0.0);
        // An untrained net predicts ≈ 1 (plus whatever its random init
        // contributes), while ground-truth inflations under load run well
        // above 1 — the tail quantile of the ratios must reflect that.
        assert!(q > 0.9, "q95 {q} far below the inflation floor");
        // Flooding the window with exact predictions drags the quantile
        // to ~1: the window demonstrably slides.
        let calm = PredictorSample {
            memory_pressure: 0.0,
            compute_demand: 0.0,
            active_instances: 0,
            concurrency: 1,
            batch: 1,
            inflation: 1.0,
        };
        let exact =
            PredictorSample { inflation: pred.predict(&calm), ..calm };
        for _ in 0..DISPERSION_WINDOW + DISPERSION_REFRESH {
            pred.observe(exact);
        }
        let q = pred.dispersion_p95();
        assert!((q - 1.0).abs() < 0.35,
                "q95 {q} did not follow the sliding window");
    }
}
