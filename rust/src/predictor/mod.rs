//! SLO-aware interference prediction (paper §IV-F): a lightweight
//! two-layer NN that learns the latency inflation caused by concurrent
//! execution, plus the linear-regression baseline it is compared against
//! in Fig. 13.

pub mod headroom;
pub mod linreg;
pub mod nn_predictor;

pub use headroom::{batches_ahead, headroom_ms, predicted_batch_cost_ms,
                   AdmissionMode, AdmissionQuantile};
pub use linreg::LinearPredictor;
pub use nn_predictor::{InterferencePredictor, PredictorSample, FEATURES};
