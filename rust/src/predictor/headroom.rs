//! Pure headroom math for predictive admission & routing (ROADMAP open
//! item 2; the SLO-aware design of SNIPPETS Snippet 3): price a
//! request's completion from the interference predictor's inflation
//! estimate and admit/route iff **headroom** = predicted e2e −
//! remaining slack ≤ 0.
//!
//! Everything here is a pure function of its arguments — no RNG, no
//! clocks, no shared state — which is what keeps the virtual arms
//! bit-deterministic per `(seed, shards)` and lets the property layer
//! (`tests/prop_headroom.rs`) pin the algebra: monotone in queue depth
//! and RTT, antitone in slack, mean-infeasible ⇒ p95-infeasible, and
//! fallback engages iff the predictor reports cold/NaN.

/// Which pricing the admission and slo-aware routing decision paths use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Today's formula: queue depth × rolling-batch-latency snapshot.
    Snapshot,
    /// Headroom from the online interference predictor, with
    /// [`AdmissionMode::Snapshot`] as the per-decision fallback whenever
    /// the predictor is cold or reports NaN.
    Predictive,
}

impl AdmissionMode {
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "snapshot" => Some(AdmissionMode::Snapshot),
            "predictive" => Some(AdmissionMode::Predictive),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AdmissionMode::Snapshot => "snapshot",
            AdmissionMode::Predictive => "predictive",
        }
    }
}

/// Which latency quantile predictive pricing targets: admit-if-mean-
/// feasible, or admit-if-p95-feasible (the prediction widened by the
/// predictor's observed dispersion).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionQuantile {
    Mean,
    P95,
}

impl AdmissionQuantile {
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "mean" => Some(AdmissionQuantile::Mean),
            "p95" => Some(AdmissionQuantile::P95),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AdmissionQuantile::Mean => "mean",
            AdmissionQuantile::P95 => "p95",
        }
    }
}

/// Batches a new arrival waits behind, counting its own. Matches the
/// snapshot formula in `serve::admission` exactly, so the predictive and
/// snapshot paths price queue depth identically and differ only in the
/// per-batch cost.
pub fn batches_ahead(queue_len: usize, ref_batch: usize) -> usize {
    queue_len / ref_batch.max(1) + 1
}

/// Quantile-adjusted predicted per-batch cost: `isolated × inflation`
/// (× the dispersion p95 at [`AdmissionQuantile::P95`]). `None` means
/// the predictor is cold or failed — non-finite or non-positive
/// inflation (e.g. the NaN an all-ex-drainer gauge lane aggregates to),
/// or a non-finite product — and the caller must fall back to the
/// snapshot formula. The p95 factor is clamped to ≥ 1 and an unknown
/// (NaN) factor degrades to exactly 1 (mean pricing), so a
/// configuration infeasible at `mean` is always infeasible at `p95`.
pub fn predicted_batch_cost_ms(isolated_ref_ms: f64, inflation: f64,
                               p95_factor: f64, q: AdmissionQuantile)
                               -> Option<f64> {
    if !(inflation.is_finite() && inflation > 0.0) {
        return None;
    }
    let factor = match q {
        AdmissionQuantile::Mean => 1.0,
        // f64::max ignores NaN, so an unknown factor yields exactly 1.
        AdmissionQuantile::P95 => 1.0f64.max(p95_factor),
    };
    let cost = isolated_ref_ms * inflation * factor;
    (cost.is_finite() && cost > 0.0).then_some(cost)
}

/// Headroom = predicted e2e − remaining slack:
/// `rtt + batches_ahead(queue) × batch_cost − slack`. Feasible iff
/// ≤ 0. Monotone nondecreasing in `queue_len`, strictly increasing in
/// `rtt_ms`, strictly decreasing in `slack_ms` (pinned by the property
/// layer).
pub fn headroom_ms(queue_len: usize, ref_batch: usize, batch_cost_ms: f64,
                   rtt_ms: f64, slack_ms: f64) -> f64 {
    rtt_ms + batches_ahead(queue_len, ref_batch) as f64 * batch_cost_ms
        - slack_ms
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_and_quantile_names_round_trip() {
        for m in [AdmissionMode::Snapshot, AdmissionMode::Predictive] {
            assert_eq!(AdmissionMode::from_name(m.name()), Some(m));
        }
        for q in [AdmissionQuantile::Mean, AdmissionQuantile::P95] {
            assert_eq!(AdmissionQuantile::from_name(q.name()), Some(q));
        }
        assert_eq!(AdmissionMode::from_name("oracle"), None);
        assert_eq!(AdmissionQuantile::from_name("p99"), None);
    }

    #[test]
    fn cold_predictor_yields_no_cost() {
        use AdmissionQuantile::*;
        for q in [Mean, P95] {
            assert_eq!(predicted_batch_cost_ms(20.0, f64::NAN, 1.2, q), None);
            assert_eq!(predicted_batch_cost_ms(20.0, 0.0, 1.2, q), None);
            assert_eq!(predicted_batch_cost_ms(20.0, -1.0, 1.2, q), None);
            assert_eq!(
                predicted_batch_cost_ms(f64::NAN, 1.5, 1.2, q), None,
                "non-finite isolated table must force the fallback");
        }
    }

    #[test]
    fn p95_is_at_least_mean_and_nan_factor_degrades_to_mean() {
        let mean =
            predicted_batch_cost_ms(20.0, 1.5, 1.3, AdmissionQuantile::Mean)
                .unwrap();
        let p95 =
            predicted_batch_cost_ms(20.0, 1.5, 1.3, AdmissionQuantile::P95)
                .unwrap();
        assert!(p95 >= mean);
        // Sub-1 and NaN dispersion both clamp to the mean cost exactly.
        for f in [0.4, f64::NAN] {
            let c =
                predicted_batch_cost_ms(20.0, 1.5, f, AdmissionQuantile::P95)
                    .unwrap();
            assert_eq!(c, mean);
        }
    }

    #[test]
    fn headroom_signs_match_feasibility() {
        // 1 batch ahead × 20 ms + 2 ms rtt = 22 ms predicted e2e.
        assert!(headroom_ms(0, 8, 20.0, 2.0, 30.0) < 0.0);
        assert!(headroom_ms(0, 8, 20.0, 2.0, 22.0) == 0.0);
        assert!(headroom_ms(0, 8, 20.0, 2.0, 15.0) > 0.0);
        // Queue depth enters in ref_batch quanta.
        assert_eq!(headroom_ms(16, 8, 20.0, 0.0, 0.0), 60.0);
    }
}
