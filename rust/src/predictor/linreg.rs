//! Linear-regression interference baseline (paper Fig. 13 compares the
//! NN predictor against "the linear regression model [16], [46]").
//!
//! Ordinary least squares on the same feature vector, solved in closed
//! form via the normal equations (Gaussian elimination on XᵀX — tiny
//! system, 6×6 with bias).

use super::nn_predictor::{PredictorSample, FEATURES};

/// OLS linear model with bias.
#[derive(Clone, Debug)]
pub struct LinearPredictor {
    /// Weights for FEATURES inputs + bias (last).
    w: [f64; FEATURES + 1],
    fitted: bool,
}

impl Default for LinearPredictor {
    fn default() -> Self {
        Self::new()
    }
}

impl LinearPredictor {
    pub fn new() -> Self {
        LinearPredictor { w: [0.0; FEATURES + 1], fitted: false }
    }

    fn design_row(s: &PredictorSample) -> [f64; FEATURES + 1] {
        let f = s.features();
        let mut row = [0.0; FEATURES + 1];
        for (i, &x) in f.iter().enumerate() {
            row[i] = x as f64;
        }
        row[FEATURES] = 1.0;
        row
    }

    /// Fit by normal equations: w = (XᵀX)⁻¹ Xᵀy.
    pub fn fit(&mut self, samples: &[PredictorSample]) {
        const D: usize = FEATURES + 1;
        let mut xtx = [[0.0f64; D]; D];
        let mut xty = [0.0f64; D];
        for s in samples {
            let row = Self::design_row(s);
            for i in 0..D {
                for j in 0..D {
                    xtx[i][j] += row[i] * row[j];
                }
                xty[i] += row[i] * s.inflation;
            }
        }
        // Ridge epsilon for numerical safety.
        for (i, row) in xtx.iter_mut().enumerate() {
            row[i] += 1e-8;
        }
        // Gaussian elimination with partial pivoting.
        let mut a = xtx;
        let mut b = xty;
        for col in 0..D {
            let mut pivot = col;
            for r in col + 1..D {
                if a[r][col].abs() > a[pivot][col].abs() {
                    pivot = r;
                }
            }
            a.swap(col, pivot);
            b.swap(col, pivot);
            let diag = a[col][col];
            if diag.abs() < 1e-12 {
                continue;
            }
            for r in 0..D {
                if r == col {
                    continue;
                }
                let factor = a[r][col] / diag;
                for c in 0..D {
                    a[r][c] -= factor * a[col][c];
                }
                b[r] -= factor * b[col];
            }
        }
        for i in 0..D {
            self.w[i] = if a[i][i].abs() < 1e-12 { 0.0 } else { b[i] / a[i][i] };
        }
        self.fitted = true;
    }

    /// Predicted inflation (floored at 1, like the NN).
    pub fn predict(&self, s: &PredictorSample) -> f64 {
        let row = Self::design_row(s);
        let y: f64 = row.iter().zip(&self.w).map(|(x, w)| x * w).sum();
        y.max(1.0)
    }

    pub fn is_fitted(&self) -> bool {
        self.fitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn linear_world(n: usize, rng: &mut Pcg32) -> Vec<PredictorSample> {
        // Ground truth IS linear here; OLS must nail it.
        (0..n)
            .map(|_| {
                let mp = rng.f64();
                let cd = rng.f64() * 4.0;
                let s = PredictorSample {
                    memory_pressure: mp,
                    compute_demand: cd,
                    active_instances: 2,
                    concurrency: 2,
                    batch: 8,
                    inflation: 1.0 + 0.5 * mp + 0.1 * cd,
                };
                s
            })
            .collect()
    }

    #[test]
    fn recovers_linear_ground_truth() {
        let mut rng = Pcg32::seeded(95);
        let data = linear_world(500, &mut rng);
        let mut lr = LinearPredictor::new();
        lr.fit(&data);
        for s in &data[..50] {
            let err = (lr.predict(s) - s.inflation).abs();
            assert!(err < 1e-6, "err {err}");
        }
    }

    #[test]
    fn underfits_nonlinear_surface() {
        // The Fig. 13 premise: a plane cannot fit the logistic memory
        // cliff. Build samples from the real interference model and check
        // the residual is materially worse than the NN test's 10 % bar.
        use crate::platform::interference::{InterferenceModel, SystemLoad};
        use crate::platform::spec::PlatformSpec;
        let mut rng = Pcg32::seeded(96);
        let model = InterferenceModel::default();
        let nx = PlatformSpec::xavier_nx();
        let data: Vec<PredictorSample> = (0..1000)
            .map(|_| {
                let load = SystemLoad {
                    active_instances: rng.range(1, 9),
                    compute_demand: rng.f64() * 6.0,
                    memory_pressure: rng.f64(),
                };
                PredictorSample {
                    memory_pressure: load.memory_pressure,
                    compute_demand: load.compute_demand,
                    active_instances: load.active_instances,
                    concurrency: load.active_instances.min(4),
                    batch: 8,
                    inflation: model.inflation(&load, &nx),
                }
            })
            .collect();
        let mut lr = LinearPredictor::new();
        lr.fit(&data);
        let mut errs: Vec<f64> = data
            .iter()
            .map(|s| (lr.predict(s) - s.inflation).abs() / s.inflation)
            .collect();
        errs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p90 = errs[(0.9 * errs.len() as f64) as usize];
        assert!(p90 > 0.05, "linreg unexpectedly good: p90 {p90}");
    }

    #[test]
    fn unfitted_predicts_floor() {
        let lr = LinearPredictor::new();
        let s = PredictorSample {
            memory_pressure: 0.5,
            compute_demand: 1.0,
            active_instances: 1,
            concurrency: 1,
            batch: 1,
            inflation: 1.0,
        };
        assert_eq!(lr.predict(&s), 1.0);
    }
}
