//! Maximum-entropy discrete Soft Actor-Critic — the paper's scheduler
//! core (§IV-B, Eqs. 5–12), following Christodoulou'19 ("Soft Actor-Critic
//! for Discrete Action Settings", the paper's ref [36]).
//!
//! Components, mapping to the paper:
//! * twin soft-Q networks + twin *target* networks — "we use two soft
//!   Q-networks and take the minimum value of them to alleviate the
//!   overestimation of soft Q-value";
//! * a categorical policy (actor) updated by minimizing the KL of Eq. (10)
//!   via the loss of Eq. (11);
//! * soft value V(s) = π(s)ᵀ[Q(s) − α log π(s)] (Eq. 8) inside the soft
//!   Bellman target of Eq. (7), trained by the residual of Eq. (9);
//! * automatic temperature tuning of Eq. (12) on log α.
//!
//! All gradients are hand-derived (see inline notes) and validated against
//! finite differences in the test suite.

use super::env::{Agent, Transition};
use super::replay::ReplayBuffer;
use crate::nn::adam::{Adam, ScalarAdam};
use crate::nn::mlp::{BackwardScratch, ForwardCache, MlpGrad};
use crate::nn::tensor::{
    log_softmax_rows, log_softmax_rows_into, softmax_rows, softmax_rows_into,
    Mat,
};
use crate::nn::Mlp;
use crate::util::rng::Pcg32;

/// Hyper-parameters (defaults = the paper's Training Details).
#[derive(Clone, Debug)]
pub struct SacConfig {
    pub hidden: Vec<usize>,
    pub lr: f32,
    pub gamma: f32,
    pub tau: f32,
    pub replay_capacity: usize,
    pub batch_size: usize,
    /// Target entropy as a fraction of the maximum ln|A|.
    pub target_entropy_ratio: f32,
    /// Environment steps before learning starts.
    pub warmup: usize,
    /// Gradient step every N observed transitions (off-policy replay makes
    /// per-step updates wasteful; amortizing 4× cuts the serving engine's
    /// wall time ~4× at equal sample reuse — EXPERIMENTS.md §Perf).
    pub update_every: usize,
}

impl Default for SacConfig {
    fn default() -> Self {
        SacConfig {
            hidden: vec![128, 64],      // paper: 128 and 64 hidden units
            lr: 1e-3,                   // paper: Adam, lr 1e-3
            gamma: 0.99,
            tau: 0.005,
            replay_capacity: 1_000_000, // paper: buffer fixed to 1e6
            batch_size: 64,             // paper trains offline at 512; 64
                                        // keeps the online variant light
            target_entropy_ratio: 0.6,
            warmup: 64,
            update_every: 4,
        }
    }
}

/// Per-update diagnostic losses.
#[derive(Clone, Copy, Debug, Default)]
pub struct SacLosses {
    pub q: f32,
    pub pi: f32,
    pub alpha: f32,
}

/// Reused buffers for the SAC action + update paths. The seed allocated
/// ~30 matrices per `update_batch` (every forward activation, every
/// gradient, the minibatch collection, masks, softmaxes) and two vectors
/// per `act`; with this scratch both are allocation-free in steady
/// state — the per-slot learning cost paper Fig. 16 measures and the
/// fig10 convergence run pays thousands of times.
struct SacScratch {
    // minibatch
    idx: Vec<usize>,
    s: Mat,
    s2: Mat,
    // shared forward ping-pong buffer
    tmp: Mat,
    // soft Bellman target
    logits2: Mat,
    pi2: Mat,
    logpi2: Mat,
    q1t: Mat,
    q2t: Mat,
    y: Vec<f32>,
    // critic update
    cache_q: ForwardCache,
    d: Mat,
    grads: MlpGrad,
    bwd: BackwardScratch,
    // actor update
    cache_pi: ForwardCache,
    pi: Mat,
    logpi: Mat,
    q1d: Mat,
    q2d: Mat,
    dpi: Mat,
    g: Vec<f32>,
    // act() path
    state_row: Mat,
    logits_row: Mat,
    probs_row: Mat,
    weights: Vec<f64>,
}

impl SacScratch {
    fn new() -> Self {
        SacScratch {
            idx: Vec::new(),
            s: Mat::zeros(0, 0),
            s2: Mat::zeros(0, 0),
            tmp: Mat::zeros(0, 0),
            logits2: Mat::zeros(0, 0),
            pi2: Mat::zeros(0, 0),
            logpi2: Mat::zeros(0, 0),
            q1t: Mat::zeros(0, 0),
            q2t: Mat::zeros(0, 0),
            y: Vec::new(),
            cache_q: ForwardCache::new(),
            d: Mat::zeros(0, 0),
            grads: Vec::new(),
            bwd: BackwardScratch::new(),
            cache_pi: ForwardCache::new(),
            pi: Mat::zeros(0, 0),
            logpi: Mat::zeros(0, 0),
            q1d: Mat::zeros(0, 0),
            q2d: Mat::zeros(0, 0),
            dpi: Mat::zeros(0, 0),
            g: Vec::new(),
            state_row: Mat::zeros(0, 0),
            logits_row: Mat::zeros(0, 0),
            probs_row: Mat::zeros(0, 0),
            weights: Vec::new(),
        }
    }
}

/// Discrete SAC agent.
pub struct DiscreteSac {
    pub cfg: SacConfig,
    n_actions: usize,
    policy: Mlp,
    q1: Mlp,
    q2: Mlp,
    q1_target: Mlp,
    q2_target: Mlp,
    opt_pi: Adam,
    opt_q1: Adam,
    opt_q2: Adam,
    log_alpha: f32,
    opt_alpha: ScalarAdam,
    target_entropy: f32,
    replay: ReplayBuffer,
    steps: usize,
    pub last_losses: SacLosses,
    scratch: SacScratch,
}

impl DiscreteSac {
    pub fn new(state_dim: usize, n_actions: usize, cfg: SacConfig,
               rng: &mut Pcg32) -> Self {
        let mut sizes = vec![state_dim];
        sizes.extend(&cfg.hidden);
        sizes.push(n_actions);
        let policy = Mlp::new(&sizes, rng);
        let q1 = Mlp::new(&sizes, rng);
        let q2 = Mlp::new(&sizes, rng);
        let q1_target = q1.clone();
        let q2_target = q2.clone();
        let opt_pi = Adam::new(&policy, cfg.lr);
        let opt_q1 = Adam::new(&q1, cfg.lr);
        let opt_q2 = Adam::new(&q2, cfg.lr);
        let target_entropy =
            cfg.target_entropy_ratio * (n_actions as f32).ln();
        let replay = ReplayBuffer::new(cfg.replay_capacity);
        DiscreteSac {
            opt_alpha: ScalarAdam::new(cfg.lr),
            cfg,
            n_actions,
            policy,
            q1,
            q2,
            q1_target,
            q2_target,
            opt_pi,
            opt_q1,
            opt_q2,
            log_alpha: 0.0,
            target_entropy,
            replay,
            steps: 0,
            last_losses: SacLosses::default(),
            scratch: SacScratch::new(),
        }
    }

    pub fn alpha(&self) -> f32 {
        self.log_alpha.exp()
    }

    /// Policy distribution π(·|s) for one state.
    pub fn policy_probs(&self, state: &[f32]) -> Vec<f32> {
        let logits = self.policy.forward(&Mat::row_vec(state));
        softmax_rows(&logits).row(0).to_vec()
    }

    /// Greedy action (argmax of the policy).
    pub fn greedy_action(&self, state: &[f32]) -> usize {
        let probs = self.policy_probs(state);
        argmax(&probs)
    }

    /// One SAC update on a replay minibatch. Allocation-free in steady
    /// state: the minibatch indices, every state/activation matrix, and
    /// every gradient buffer live in the private `SacScratch` and are
    /// recycled across updates.
    pub fn update_batch(&mut self, rng: &mut Pcg32) -> SacLosses {
        if self.replay.len() < self.cfg.warmup.max(self.cfg.batch_size) {
            return SacLosses::default();
        }
        let n = self.cfg.batch_size;
        let a = self.n_actions;
        let alpha = self.alpha();
        let sc = &mut self.scratch;
        self.replay.sample_indices_into(n, rng, &mut sc.idx);

        let dim = self.replay.get(sc.idx[0]).state.len();
        sc.s.reset(n, dim);
        sc.s2.reset(n, dim);
        for (r, &i) in sc.idx.iter().enumerate() {
            let t = self.replay.get(i);
            sc.s.row_mut(r).copy_from_slice(&t.state);
            sc.s2.row_mut(r).copy_from_slice(&t.next_state);
        }

        // --- Soft Bellman target (Eqs. 7–8) ------------------------------
        // V(s') = π(s')ᵀ [min(Q̄₁, Q̄₂)(s') − α log π(s')]
        self.policy.forward_into(&sc.s2, &mut sc.logits2, &mut sc.tmp);
        softmax_rows_into(&sc.logits2, &mut sc.pi2);
        log_softmax_rows_into(&sc.logits2, &mut sc.logpi2);
        self.q1_target.forward_into(&sc.s2, &mut sc.q1t, &mut sc.tmp);
        self.q2_target.forward_into(&sc.s2, &mut sc.q2t, &mut sc.tmp);
        sc.y.clear();
        for i in 0..n {
            let mut v = 0.0;
            for j in 0..a {
                let qmin = sc.q1t.at(i, j).min(sc.q2t.at(i, j));
                v += sc.pi2.at(i, j) * (qmin - alpha * sc.logpi2.at(i, j));
            }
            let t = self.replay.get(sc.idx[i]);
            sc.y.push(t.reward
                + self.cfg.gamma * if t.done { 0.0 } else { v });
        }

        // --- Critic update (Eq. 9): MSE on the taken action only ---------
        let mut q_loss_total = 0.0;
        for (qnet, opt) in [(&mut self.q1, &mut self.opt_q1),
                            (&mut self.q2, &mut self.opt_q2)] {
            qnet.forward_cache_into(&sc.s, &mut sc.cache_q);
            sc.d.reset(n, a);
            sc.d.data_mut().fill(0.0);
            let mut loss = 0.0;
            for i in 0..n {
                let act = self.replay.get(sc.idx[i]).action;
                let e = sc.cache_q.output().at(i, act) - sc.y[i];
                loss += 0.5 * e * e / n as f32;
                *sc.d.at_mut(i, act) = e / n as f32;
            }
            qnet.backward_into(&sc.cache_q, &sc.d, &mut sc.grads, &mut sc.bwd);
            opt.step(qnet, &sc.grads);
            q_loss_total += loss;
        }

        // --- Actor update (Eq. 11) ----------------------------------------
        // J_π = E_s Σ_a π(a|s) [α log π(a|s) − min Q(s,a)]
        // With z the logits, g_a = α log π_a − Q_a:
        //   ∂J/∂z_k = π_k [ (g_k + α) − Σ_a π_a (g_a + α) ]
        // (softmax Jacobian applied to ∂J/∂π_a = g_a + α).
        self.policy.forward_cache_into(&sc.s, &mut sc.cache_pi);
        softmax_rows_into(sc.cache_pi.output(), &mut sc.pi);
        log_softmax_rows_into(sc.cache_pi.output(), &mut sc.logpi);
        self.q1.forward_into(&sc.s, &mut sc.q1d, &mut sc.tmp);
        self.q2.forward_into(&sc.s, &mut sc.q2d, &mut sc.tmp);
        sc.dpi.reset(n, a);
        sc.dpi.data_mut().fill(0.0);
        sc.g.clear();
        sc.g.resize(a, 0.0);
        let mut pi_loss = 0.0;
        let mut entropy_err_sum = 0.0;
        for i in 0..n {
            let mut mean_term = 0.0;
            for j in 0..a {
                let qmin = sc.q1d.at(i, j).min(sc.q2d.at(i, j));
                sc.g[j] = alpha * sc.logpi.at(i, j) - qmin;
                pi_loss += sc.pi.at(i, j) * sc.g[j] / n as f32;
                mean_term += sc.pi.at(i, j) * (sc.g[j] + alpha);
            }
            for j in 0..a {
                *sc.dpi.at_mut(i, j) =
                    sc.pi.at(i, j) * (sc.g[j] + alpha - mean_term) / n as f32;
            }
            // Entropy error for the temperature update (Eq. 12):
            // Σ_a π_a (log π_a + H̄)  — positive when entropy is too low.
            for j in 0..a {
                entropy_err_sum +=
                    sc.pi.at(i, j) * (sc.logpi.at(i, j) + self.target_entropy);
            }
        }
        self.policy.backward_into(&sc.cache_pi, &sc.dpi, &mut sc.grads,
                                  &mut sc.bwd);
        self.opt_pi.step(&mut self.policy, &sc.grads);

        // --- Temperature update (Eq. 12) ----------------------------------
        // J(α) = E[−α (log π + H̄)]; ∂J/∂(log α) = −α · E[log π + H̄].
        // J(α) = −α·err ⇒ ∂J/∂α = −err ⇒ ∂J/∂(log α) = −α·err.
        let entropy_err = entropy_err_sum / n as f32;
        let alpha_grad = -alpha * entropy_err;
        self.log_alpha += self.opt_alpha.step(alpha_grad);
        self.log_alpha = self.log_alpha.clamp(-10.0, 2.0);
        let alpha_loss = -self.alpha() * entropy_err;

        // --- Polyak target update -----------------------------------------
        self.q1_target.soft_update_from(&self.q1, self.cfg.tau);
        self.q2_target.soft_update_from(&self.q2, self.cfg.tau);

        let losses = SacLosses { q: q_loss_total, pi: pi_loss, alpha: alpha_loss };
        self.last_losses = losses;
        losses
    }

    /// Faithful port of the SEED's allocating update step, kept as a
    /// bench/test oracle (like `ModelQueue::*_naive_ms`): fresh
    /// minibatch collection, fresh state matrices, allocating
    /// forward/backward. Consumes the RNG identically to
    /// [`DiscreteSac::update_batch`] and computes the same float
    /// operations in the same order, so identically-seeded agents stay
    /// bit-identical whichever path they take — proven by
    /// `alloc_oracle_matches_scratch_update`. `benches/hotpath_engine.rs`
    /// times both to report the update-step speedup.
    pub fn update_batch_alloc(&mut self, rng: &mut Pcg32) -> SacLosses {
        if self.replay.len() < self.cfg.warmup.max(self.cfg.batch_size) {
            return SacLosses::default();
        }
        let batch = self.replay.sample(self.cfg.batch_size, rng);
        let n = batch.len();
        let a = self.n_actions;
        let alpha = self.alpha();

        fn states_mat(batch: &[&Transition], next: bool) -> Mat {
            let dim = batch[0].state.len();
            let mut m = Mat::zeros(batch.len(), dim);
            for (i, t) in batch.iter().enumerate() {
                let src = if next { &t.next_state } else { &t.state };
                m.row_mut(i).copy_from_slice(src);
            }
            m
        }
        let s = states_mat(&batch, false);
        let s2 = states_mat(&batch, true);

        // Soft Bellman target (Eqs. 7–8).
        let logits2 = self.policy.forward(&s2);
        let pi2 = softmax_rows(&logits2);
        let logpi2 = log_softmax_rows(&logits2);
        let q1t = self.q1_target.forward(&s2);
        let q2t = self.q2_target.forward(&s2);
        let mut y = vec![0.0f32; n];
        for i in 0..n {
            let mut v = 0.0;
            for j in 0..a {
                let qmin = q1t.at(i, j).min(q2t.at(i, j));
                v += pi2.at(i, j) * (qmin - alpha * logpi2.at(i, j));
            }
            let t = &batch[i];
            y[i] = t.reward
                + self.cfg.gamma * if t.done { 0.0 } else { v };
        }

        // Critic update (Eq. 9).
        let mut q_loss_total = 0.0;
        for (qnet, opt) in [(&mut self.q1, &mut self.opt_q1),
                            (&mut self.q2, &mut self.opt_q2)] {
            let cache = qnet.forward_cache(&s);
            let qs = cache.output();
            let mut d = Mat::zeros(n, a);
            let mut loss = 0.0;
            for i in 0..n {
                let act = batch[i].action;
                let e = qs.at(i, act) - y[i];
                loss += 0.5 * e * e / n as f32;
                *d.at_mut(i, act) = e / n as f32;
            }
            let grads = qnet.backward(&cache, &d);
            opt.step(qnet, &grads);
            q_loss_total += loss;
        }

        // Actor update (Eq. 11).
        let cache_pi = self.policy.forward_cache(&s);
        let logits = cache_pi.output();
        let pi = softmax_rows(logits);
        let logpi = log_softmax_rows(logits);
        let q1d = self.q1.forward(&s);
        let q2d = self.q2.forward(&s);
        let mut dpi = Mat::zeros(n, a);
        let mut pi_loss = 0.0;
        let mut entropy_err_sum = 0.0;
        for i in 0..n {
            let mut mean_term = 0.0;
            let mut g = vec![0.0f32; a];
            for j in 0..a {
                let qmin = q1d.at(i, j).min(q2d.at(i, j));
                g[j] = alpha * logpi.at(i, j) - qmin;
                pi_loss += pi.at(i, j) * g[j] / n as f32;
                mean_term += pi.at(i, j) * (g[j] + alpha);
            }
            for j in 0..a {
                *dpi.at_mut(i, j) =
                    pi.at(i, j) * (g[j] + alpha - mean_term) / n as f32;
            }
            for j in 0..a {
                entropy_err_sum +=
                    pi.at(i, j) * (logpi.at(i, j) + self.target_entropy);
            }
        }
        let grads_pi = self.policy.backward(&cache_pi, &dpi);
        self.opt_pi.step(&mut self.policy, &grads_pi);

        // Temperature update (Eq. 12).
        let entropy_err = entropy_err_sum / n as f32;
        let alpha_grad = -alpha * entropy_err;
        self.log_alpha += self.opt_alpha.step(alpha_grad);
        self.log_alpha = self.log_alpha.clamp(-10.0, 2.0);
        let alpha_loss = -self.alpha() * entropy_err;

        // Polyak target update.
        self.q1_target.soft_update_from(&self.q1, self.cfg.tau);
        self.q2_target.soft_update_from(&self.q2, self.cfg.tau);

        let losses = SacLosses { q: q_loss_total, pi: pi_loss, alpha: alpha_loss };
        self.last_losses = losses;
        losses
    }

    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }

    /// Serialize the actor (deployment checkpoint — critics/temperature
    /// are training-only state).
    pub fn policy_json(&self) -> crate::util::json::Json {
        self.policy.to_json()
    }

    /// Load an actor checkpoint (must match state/action dims).
    pub fn load_policy(&mut self, v: &crate::util::json::Json)
                       -> Result<(), String> {
        let net = Mlp::from_json(v)?;
        if net.in_dim() != self.policy.in_dim()
            || net.out_dim() != self.n_actions
        {
            return Err(format!(
                "checkpoint shape {}→{} does not match policy {}→{}",
                net.in_dim(),
                net.out_dim(),
                self.policy.in_dim(),
                self.n_actions
            ));
        }
        self.policy = net;
        Ok(())
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

impl Agent for DiscreteSac {
    /// Decision hot path (runs once per busy model per scheduling round):
    /// the state row, forward activations, probabilities, and sampling
    /// weights all live in the reused scratch — no allocation per
    /// decision, unlike the allocating [`DiscreteSac::policy_probs`]
    /// convenience path.
    fn act(&mut self, state: &[f32], rng: &mut Pcg32, greedy: bool) -> usize {
        let sc = &mut self.scratch;
        sc.state_row.reset(1, state.len());
        sc.state_row.row_mut(0).copy_from_slice(state);
        self.policy.forward_into(&sc.state_row, &mut sc.logits_row,
                                 &mut sc.tmp);
        softmax_rows_into(&sc.logits_row, &mut sc.probs_row);
        let probs = sc.probs_row.row(0);
        if greedy {
            argmax(probs)
        } else {
            sc.weights.clear();
            sc.weights.extend(probs.iter().map(|&p| p as f64));
            rng.categorical(&sc.weights)
        }
    }

    fn observe(&mut self, t: Transition) {
        self.steps += 1;
        self.replay.push(t);
    }

    fn update(&mut self, rng: &mut Pcg32) -> f32 {
        if self.cfg.update_every > 1
            && self.steps % self.cfg.update_every != 0
        {
            return self.last_losses.q + self.last_losses.pi.abs();
        }
        let l = self.update_batch(rng);
        l.q + l.pi.abs()
    }

    fn name(&self) -> &'static str {
        "SAC (BCEdge)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::env::testenv::Chain;
    use crate::rl::env::{train_episodes, Env};

    #[test]
    fn policy_is_distribution() {
        let mut rng = Pcg32::seeded(41);
        let sac = DiscreteSac::new(4, 6, SacConfig::default(), &mut rng);
        let p = sac.policy_probs(&[0.1, -0.5, 1.0, 0.0]);
        assert_eq!(p.len(), 6);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn update_noop_before_warmup() {
        let mut rng = Pcg32::seeded(42);
        let mut sac = DiscreteSac::new(2, 3, SacConfig::default(), &mut rng);
        let l = sac.update_batch(&mut rng);
        assert_eq!(l.q, 0.0);
    }

    #[test]
    fn actor_gradient_matches_finite_difference() {
        // Check ∂J_π/∂logits against numeric differentiation of
        // J = Σ_a π_a (α log π_a − Q_a) for a single state.
        let alpha = 0.37f32;
        let q = [0.5f32, -1.0, 2.0];
        let logits = [0.2f32, -0.3, 0.8];
        let j = |z: &[f32; 3]| -> f32 {
            let m = Mat::row_vec(z);
            let pi = softmax_rows(&m);
            let lp = log_softmax_rows(&m);
            (0..3)
                .map(|i| pi.at(0, i) * (alpha * lp.at(0, i) - q[i]))
                .sum()
        };
        // analytic
        let m = Mat::row_vec(&logits);
        let pi = softmax_rows(&m);
        let lp = log_softmax_rows(&m);
        let g: Vec<f32> =
            (0..3).map(|i| alpha * lp.at(0, i) - q[i]).collect();
        let mean: f32 =
            (0..3).map(|i| pi.at(0, i) * (g[i] + alpha)).sum();
        for k in 0..3 {
            let ana = pi.at(0, k) * (g[k] + alpha - mean);
            let eps = 1e-3;
            let mut zp = logits;
            zp[k] += eps;
            let mut zm = logits;
            zm[k] -= eps;
            let num = (j(&zp) - j(&zm)) / (2.0 * eps);
            assert!(
                (num - ana).abs() < 1e-3,
                "k={k}: numeric {num} analytic {ana}"
            );
        }
    }

    /// The scratch-based update must be bit-identical to the seed's
    /// allocating oracle: same RNG consumption, same float-op order,
    /// same resulting policy.
    #[test]
    fn alloc_oracle_matches_scratch_update() {
        let mk = || {
            let mut rng = Pcg32::seeded(0xD0E);
            let cfg = SacConfig {
                warmup: 32,
                batch_size: 32,
                ..Default::default()
            };
            let mut sac = DiscreteSac::new(5, 4, cfg, &mut rng);
            let mut feed = Pcg32::seeded(0xFEED);
            for _ in 0..64 {
                let s: Vec<f32> =
                    (0..5).map(|_| feed.f32() * 2.0 - 1.0).collect();
                let s2: Vec<f32> =
                    (0..5).map(|_| feed.f32() * 2.0 - 1.0).collect();
                let a = sac.act(&s, &mut feed, false);
                sac.observe(Transition {
                    state: s,
                    action: a,
                    reward: feed.f32() * 4.0 - 2.0,
                    next_state: s2,
                    done: feed.below(10) == 0,
                });
            }
            sac
        };
        let mut opt = mk();
        let mut seed = mk();
        let mut r1 = Pcg32::seeded(0x0B5);
        let mut r2 = Pcg32::seeded(0x0B5);
        for step in 0..5 {
            let la = opt.update_batch(&mut r1);
            let lb = seed.update_batch_alloc(&mut r2);
            assert_eq!(la.q, lb.q, "q loss diverged at step {step}");
            assert_eq!(la.pi, lb.pi, "pi loss diverged at step {step}");
            assert_eq!(la.alpha, lb.alpha, "alpha loss diverged at {step}");
        }
        let probe = [0.3f32, -0.7, 0.1, 0.9, -0.2];
        assert_eq!(opt.policy_probs(&probe), seed.policy_probs(&probe));
        assert_eq!(opt.alpha(), seed.alpha());
    }

    #[test]
    fn learns_chain_mdp() {
        let mut rng = Pcg32::seeded(43);
        let mut env = Chain::new(5);
        let cfg = SacConfig {
            warmup: 32,
            batch_size: 32,
            lr: 3e-3,
            ..SacConfig::default()
        };
        let mut sac =
            DiscreteSac::new(env.state_dim(), env.n_actions(), cfg, &mut rng);
        let hist = train_episodes(&mut env, &mut sac, 60, 30, &mut rng);
        let late: f32 =
            hist[hist.len() - 10..].iter().map(|x| x.0).sum::<f32>() / 10.0;
        assert!(late > 0.8, "did not learn chain: late return {late}");
    }

    #[test]
    fn temperature_stays_bounded() {
        let mut rng = Pcg32::seeded(44);
        let mut env = Chain::new(4);
        let mut sac = DiscreteSac::new(
            env.state_dim(),
            env.n_actions(),
            SacConfig { warmup: 16, batch_size: 16, ..Default::default() },
            &mut rng,
        );
        train_episodes(&mut env, &mut sac, 30, 20, &mut rng);
        let a = sac.alpha();
        assert!(a.is_finite() && a > 0.0 && a < 10.0, "alpha {a}");
    }
}
