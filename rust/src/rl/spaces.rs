//! The two-dimensional discrete action space of paper §IV-B: an action is
//! a (batch size, number of concurrent model instances) pair, so with M
//! batch options and N concurrency options the space has M × N actions
//! ("the size of the discrete action space A is M × N").

/// Cartesian action grid over batch sizes × concurrency levels.
#[derive(Clone, Debug, PartialEq)]
pub struct ActionSpace {
    batch_sizes: Vec<usize>,
    concurrency: Vec<usize>,
}

impl ActionSpace {
    pub fn new(batch_sizes: Vec<usize>, concurrency: Vec<usize>) -> Self {
        assert!(!batch_sizes.is_empty() && !concurrency.is_empty());
        ActionSpace { batch_sizes, concurrency }
    }

    /// The compiled-artifact grid: batch ∈ {1..32} pow2 × m_c ∈ {1..4}.
    pub fn standard() -> Self {
        ActionSpace::new(vec![1, 2, 4, 8, 16, 32], vec![1, 2, 3, 4])
    }

    /// The wider simulation-only grid matching paper Fig. 1 extremes
    /// (batch up to 128, m_c up to 8).
    pub fn sim_wide() -> Self {
        ActionSpace::new(vec![1, 2, 4, 8, 16, 32, 64, 128],
                         vec![1, 2, 3, 4, 5, 6, 7, 8])
    }

    pub fn len(&self) -> usize {
        self.batch_sizes.len() * self.concurrency.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }

    pub fn concurrency_levels(&self) -> &[usize] {
        &self.concurrency
    }

    /// Action index → (batch, concurrency).
    pub fn decode(&self, idx: usize) -> (usize, usize) {
        assert!(idx < self.len(), "action {idx} out of range");
        let nb = self.batch_sizes.len();
        (self.batch_sizes[idx % nb], self.concurrency[idx / nb])
    }

    /// (batch, concurrency) → action index; `None` if not on the grid.
    pub fn encode(&self, batch: usize, conc: usize) -> Option<usize> {
        let bi = self.batch_sizes.iter().position(|&b| b == batch)?;
        let ci = self.concurrency.iter().position(|&c| c == conc)?;
        Some(ci * self.batch_sizes.len() + bi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_grid_is_paper_sized() {
        let a = ActionSpace::standard();
        assert_eq!(a.len(), 24); // 6 batch sizes × 4 concurrency levels
    }

    #[test]
    fn decode_encode_round_trip() {
        let a = ActionSpace::sim_wide();
        for idx in 0..a.len() {
            let (b, c) = a.decode(idx);
            assert_eq!(a.encode(b, c), Some(idx));
        }
    }

    #[test]
    fn encode_rejects_off_grid() {
        let a = ActionSpace::standard();
        assert_eq!(a.encode(3, 1), None);
        assert_eq!(a.encode(1, 9), None);
    }

    #[test]
    #[should_panic]
    fn decode_out_of_range_panics() {
        ActionSpace::standard().decode(24);
    }
}
