//! Proximal Policy Optimization baseline (paper §V-B, ref [44]):
//! on-policy, clipped surrogate objective, GAE(λ) advantages.

use super::env::{Agent, Transition};
use crate::nn::adam::Adam;
use crate::nn::tensor::{log_softmax_rows, softmax_rows, Mat};
use crate::nn::Mlp;
use crate::util::rng::Pcg32;

/// PPO hyper-parameters.
#[derive(Clone, Debug)]
pub struct PpoConfig {
    pub hidden: Vec<usize>,
    pub lr: f32,
    pub gamma: f32,
    pub lambda: f32,
    pub clip: f32,
    /// Rollout length before each policy update.
    pub horizon: usize,
    /// Gradient epochs per rollout.
    pub epochs: usize,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            hidden: vec![128, 64],
            lr: 1e-3,
            gamma: 0.99,
            lambda: 0.95,
            clip: 0.2,
            horizon: 64,
            epochs: 4,
        }
    }
}

struct RolloutItem {
    t: Transition,
    logp_old: f32,
}

/// PPO agent with separate actor/critic MLPs.
pub struct Ppo {
    cfg: PpoConfig,
    n_actions: usize,
    actor: Mlp,
    critic: Mlp,
    opt_actor: Adam,
    opt_critic: Adam,
    rollout: Vec<RolloutItem>,
    last_logp: f32,
}

impl Ppo {
    pub fn new(state_dim: usize, n_actions: usize, cfg: PpoConfig,
               rng: &mut Pcg32) -> Self {
        let mut pi_sizes = vec![state_dim];
        pi_sizes.extend(&cfg.hidden);
        pi_sizes.push(n_actions);
        let mut v_sizes = vec![state_dim];
        v_sizes.extend(&cfg.hidden);
        v_sizes.push(1);
        let actor = Mlp::new(&pi_sizes, rng);
        let critic = Mlp::new(&v_sizes, rng);
        let opt_actor = Adam::new(&actor, cfg.lr);
        let opt_critic = Adam::new(&critic, cfg.lr);
        Ppo {
            cfg,
            n_actions,
            actor,
            critic,
            opt_actor,
            opt_critic,
            rollout: Vec::new(),
            last_logp: 0.0,
        }
    }

    fn train_on_rollout(&mut self) -> f32 {
        let n = self.rollout.len();
        if n == 0 {
            return 0.0;
        }
        let dim = self.rollout[0].t.state.len();
        let mut s = Mat::zeros(n, dim);
        for (i, item) in self.rollout.iter().enumerate() {
            s.row_mut(i).copy_from_slice(&item.t.state);
        }
        // Values for GAE.
        let values: Vec<f32> =
            (0..n).map(|i| self.critic.forward(&Mat::row_vec(&self.rollout[i].t.state)).at(0, 0)).collect();
        let mut adv = vec![0.0f32; n];
        let mut ret = vec![0.0f32; n];
        let mut gae = 0.0f32;
        for i in (0..n).rev() {
            let t = &self.rollout[i].t;
            let v_next = if t.done {
                0.0
            } else if i + 1 < n {
                values[i + 1]
            } else {
                self.critic.forward(&Mat::row_vec(&t.next_state)).at(0, 0)
            };
            let delta = t.reward + self.cfg.gamma * v_next - values[i];
            gae = delta
                + self.cfg.gamma
                    * self.cfg.lambda
                    * if t.done { 0.0 } else { gae };
            adv[i] = gae;
            ret[i] = adv[i] + values[i];
        }
        // Normalize advantages.
        let mean = adv.iter().sum::<f32>() / n as f32;
        let var =
            adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / n as f32;
        let std = var.sqrt().max(1e-6);
        for a in adv.iter_mut() {
            *a = (*a - mean) / std;
        }

        let mut last_loss = 0.0;
        for _ in 0..self.cfg.epochs {
            // Actor: clipped surrogate.
            let cache_pi = self.actor.forward_cache(&s);
            let pi = softmax_rows(cache_pi.output());
            let logpi = log_softmax_rows(cache_pi.output());
            let mut d = Mat::zeros(n, self.n_actions);
            let mut loss = 0.0;
            for i in 0..n {
                let a = self.rollout[i].t.action;
                let ratio =
                    (logpi.at(i, a) - self.rollout[i].logp_old).exp();
                let clipped = ratio.clamp(1.0 - self.cfg.clip, 1.0 + self.cfg.clip);
                let use_unclipped = ratio * adv[i] <= clipped * adv[i];
                loss += -(ratio * adv[i]).min(clipped * adv[i]) / n as f32;
                // Gradient flows only through the unclipped branch when it
                // is the active min.
                if use_unclipped {
                    // ∂(−ratio·A)/∂z_k = −A·ratio·(δ_ak − π_k)
                    for k in 0..self.n_actions {
                        let delta = if k == a { 1.0 } else { 0.0 };
                        *d.at_mut(i, k) +=
                            -adv[i] * ratio * (delta - pi.at(i, k)) / n as f32;
                    }
                }
            }
            let grads_pi = self.actor.backward(&cache_pi, &d);
            self.opt_actor.step(&mut self.actor, &grads_pi);

            // Critic: MSE to returns.
            let cache_v = self.critic.forward_cache(&s);
            let v = cache_v.output();
            let mut dv = Mat::zeros(n, 1);
            let mut v_loss = 0.0;
            for i in 0..n {
                let e = v.at(i, 0) - ret[i];
                v_loss += e * e / n as f32;
                *dv.at_mut(i, 0) = 2.0 * e / n as f32;
            }
            let grads_v = self.critic.backward(&cache_v, &dv);
            self.opt_critic.step(&mut self.critic, &grads_v);
            last_loss = loss + v_loss;
        }
        self.rollout.clear();
        last_loss
    }
}

impl Agent for Ppo {
    fn act(&mut self, state: &[f32], rng: &mut Pcg32, greedy: bool) -> usize {
        let logits = self.actor.forward(&Mat::row_vec(state));
        let pi = softmax_rows(&logits);
        let logpi = log_softmax_rows(&logits);
        let action = if greedy {
            (0..self.n_actions)
                .max_by(|&a, &b| pi.at(0, a).partial_cmp(&pi.at(0, b)).unwrap())
                .unwrap()
        } else {
            let w: Vec<f64> =
                (0..self.n_actions).map(|k| pi.at(0, k) as f64).collect();
            rng.categorical(&w)
        };
        self.last_logp = logpi.at(0, action);
        action
    }

    fn observe(&mut self, t: Transition) {
        self.rollout.push(RolloutItem { t, logp_old: self.last_logp });
    }

    fn update(&mut self, _rng: &mut Pcg32) -> f32 {
        let flush = self.rollout.len() >= self.cfg.horizon
            || self.rollout.last().map(|r| r.t.done).unwrap_or(false);
        if flush {
            self.train_on_rollout()
        } else {
            0.0
        }
    }

    fn name(&self) -> &'static str {
        "PPO"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::env::testenv::Chain;
    use crate::rl::env::{train_episodes, Env};

    #[test]
    fn learns_chain_mdp() {
        let mut rng = Pcg32::seeded(71);
        let mut env = Chain::new(4);
        let mut agent = Ppo::new(
            env.state_dim(),
            env.n_actions(),
            PpoConfig { horizon: 32, lr: 3e-3, ..Default::default() },
            &mut rng,
        );
        let hist = train_episodes(&mut env, &mut agent, 120, 25, &mut rng);
        let late: f32 =
            hist[hist.len() - 15..].iter().map(|x| x.0).sum::<f32>() / 15.0;
        assert!(late > 0.6, "did not learn chain: late return {late}");
    }

    #[test]
    fn rollout_clears_after_update() {
        let mut rng = Pcg32::seeded(72);
        let mut agent = Ppo::new(
            2,
            2,
            PpoConfig { horizon: 2, ..Default::default() },
            &mut rng,
        );
        for i in 0..2 {
            let a = agent.act(&[0.0, 1.0], &mut rng, false);
            agent.observe(Transition {
                state: vec![0.0, 1.0],
                action: a,
                reward: i as f32,
                next_state: vec![1.0, 0.0],
                done: false,
            });
        }
        agent.update(&mut rng);
        assert!(agent.rollout.is_empty());
    }
}
