//! Uniform replay buffer (paper: "the buffer size is fixed to 10^6").

use super::env::Transition;
use crate::util::rng::Pcg32;

/// Fixed-capacity ring buffer with uniform sampling.
pub struct ReplayBuffer {
    capacity: usize,
    items: Vec<Transition>,
    next: usize,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ReplayBuffer { capacity, items: Vec::new(), next: 0 }
    }

    pub fn push(&mut self, t: Transition) {
        if self.items.len() < self.capacity {
            self.items.push(t);
        } else {
            self.items[self.next] = t;
        }
        self.next = (self.next + 1) % self.capacity;
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Access a transition by index (for index-based minibatch sampling).
    pub fn get(&self, i: usize) -> &Transition {
        &self.items[i]
    }

    /// Sample `n` indices uniformly with replacement into a reused
    /// buffer. Index-based sampling lets the SAC update loop keep its
    /// minibatch buffer across steps instead of collecting a fresh
    /// `Vec<&Transition>` every update; the RNG call sequence is
    /// identical to [`ReplayBuffer::sample`].
    pub fn sample_indices_into(&self, n: usize, rng: &mut Pcg32,
                               out: &mut Vec<usize>) {
        assert!(!self.items.is_empty(), "sampling empty replay buffer");
        out.clear();
        out.extend((0..n).map(|_| rng.below(self.items.len() as u32) as usize));
    }

    /// Sample `n` transitions uniformly with replacement.
    pub fn sample<'a>(&'a self, n: usize, rng: &mut Pcg32) -> Vec<&'a Transition> {
        assert!(!self.items.is_empty(), "sampling empty replay buffer");
        (0..n)
            .map(|_| &self.items[rng.below(self.items.len() as u32) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(reward: f32) -> Transition {
        Transition {
            state: vec![0.0],
            action: 0,
            reward,
            next_state: vec![0.0],
            done: false,
        }
    }

    #[test]
    fn wraps_at_capacity() {
        let mut buf = ReplayBuffer::new(3);
        for i in 0..5 {
            buf.push(t(i as f32));
        }
        assert_eq!(buf.len(), 3);
        // items 2, 3, 4 survive (0 and 1 overwritten)
        let rewards: Vec<f32> = buf.items.iter().map(|x| x.reward).collect();
        assert!(rewards.contains(&4.0) && rewards.contains(&3.0) && rewards.contains(&2.0));
    }

    #[test]
    fn sample_returns_requested_count() {
        let mut buf = ReplayBuffer::new(10);
        for i in 0..4 {
            buf.push(t(i as f32));
        }
        let mut rng = Pcg32::seeded(1);
        assert_eq!(buf.sample(32, &mut rng).len(), 32);
    }

    #[test]
    #[should_panic]
    fn sample_empty_panics() {
        ReplayBuffer::new(4).sample(1, &mut Pcg32::seeded(0));
    }

    #[test]
    fn index_sampling_matches_ref_sampling() {
        let mut buf = ReplayBuffer::new(16);
        for i in 0..9 {
            buf.push(t(i as f32));
        }
        let mut r1 = Pcg32::seeded(42);
        let mut r2 = Pcg32::seeded(42);
        let refs = buf.sample(32, &mut r1);
        let mut idx = Vec::new();
        buf.sample_indices_into(32, &mut r2, &mut idx);
        assert_eq!(idx.len(), 32);
        for (r, &i) in refs.iter().zip(&idx) {
            assert_eq!(r.reward, buf.get(i).reward);
        }
    }
}
