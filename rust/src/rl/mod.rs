//! Reinforcement-learning substrate: the maximum-entropy discrete SAC
//! scheduler of paper §IV-B plus every baseline of §V-B (PPO, DDQN,
//! entropy-free actor-critic for "TAC", and the genetic algorithm).
//!
//! All agents implement [`Agent`] over a discrete action grid
//! ([`spaces::ActionSpace`] = batch size × concurrent instances) so the
//! coordinator can swap schedulers behind one interface, and every network
//! is the paper's 2-layer ReLU MLP (128/64) trained with Adam 1e-3.

pub mod ac;
pub mod ddqn;
pub mod env;
pub mod ga;
pub mod ppo;
pub mod replay;
pub mod sac;
pub mod spaces;

pub use env::{Agent, Env, Transition};
pub use replay::ReplayBuffer;
pub use sac::DiscreteSac;
pub use spaces::ActionSpace;
