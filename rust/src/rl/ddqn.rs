//! Double Deep Q-Network baseline (paper §V-B: "DDQN eliminates
//! overestimation by decoupling the selection of actions in target Q-value
//! and the calculation of target Q-value"), ref [45].

use super::env::{Agent, Transition};
use super::replay::ReplayBuffer;
use crate::nn::adam::Adam;
use crate::nn::loss::huber;
use crate::nn::tensor::Mat;
use crate::nn::Mlp;
use crate::util::rng::Pcg32;

/// DDQN hyper-parameters.
#[derive(Clone, Debug)]
pub struct DdqnConfig {
    pub hidden: Vec<usize>,
    pub lr: f32,
    pub gamma: f32,
    pub replay_capacity: usize,
    pub batch_size: usize,
    pub warmup: usize,
    /// ε-greedy schedule: linear decay from start to end over decay_steps.
    pub eps_start: f32,
    pub eps_end: f32,
    pub eps_decay_steps: usize,
    /// Hard target-network sync period (in updates).
    pub target_sync: usize,
    /// Gradient step every N observed transitions (see SacConfig).
    pub update_every: usize,
}

impl Default for DdqnConfig {
    fn default() -> Self {
        DdqnConfig {
            hidden: vec![128, 64],
            lr: 1e-3,
            gamma: 0.99,
            replay_capacity: 1_000_000,
            batch_size: 64,
            warmup: 64,
            eps_start: 1.0,
            eps_end: 0.05,
            eps_decay_steps: 2_000,
            target_sync: 100,
            update_every: 4,
        }
    }
}

/// Double DQN agent.
pub struct Ddqn {
    cfg: DdqnConfig,
    n_actions: usize,
    q: Mlp,
    q_target: Mlp,
    opt: Adam,
    replay: ReplayBuffer,
    steps: usize,
    updates: usize,
}

impl Ddqn {
    pub fn new(state_dim: usize, n_actions: usize, cfg: DdqnConfig,
               rng: &mut Pcg32) -> Self {
        let mut sizes = vec![state_dim];
        sizes.extend(&cfg.hidden);
        sizes.push(n_actions);
        let q = Mlp::new(&sizes, rng);
        let q_target = q.clone();
        let opt = Adam::new(&q, cfg.lr);
        let replay = ReplayBuffer::new(cfg.replay_capacity);
        Ddqn { cfg, n_actions, q, q_target, opt, replay, steps: 0, updates: 0 }
    }

    fn epsilon(&self) -> f32 {
        let frac =
            (self.steps as f32 / self.cfg.eps_decay_steps as f32).min(1.0);
        self.cfg.eps_start + (self.cfg.eps_end - self.cfg.eps_start) * frac
    }

    fn argmax_row(m: &Mat, row: usize) -> usize {
        let r = m.row(row);
        r.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    }
}

impl Agent for Ddqn {
    fn act(&mut self, state: &[f32], rng: &mut Pcg32, greedy: bool) -> usize {
        if !greedy && rng.f32() < self.epsilon() {
            return rng.below(self.n_actions as u32) as usize;
        }
        let q = self.q.forward(&Mat::row_vec(state));
        Self::argmax_row(&q, 0)
    }

    fn observe(&mut self, t: Transition) {
        self.steps += 1;
        self.replay.push(t);
    }

    fn update(&mut self, rng: &mut Pcg32) -> f32 {
        if self.replay.len() < self.cfg.warmup.max(self.cfg.batch_size) {
            return 0.0;
        }
        if self.cfg.update_every > 1
            && self.steps % self.cfg.update_every != 0
        {
            return 0.0;
        }
        let batch = self.replay.sample(self.cfg.batch_size, rng);
        let n = batch.len();
        let dim = batch[0].state.len();
        let mut s = Mat::zeros(n, dim);
        let mut s2 = Mat::zeros(n, dim);
        for (i, t) in batch.iter().enumerate() {
            s.row_mut(i).copy_from_slice(&t.state);
            s2.row_mut(i).copy_from_slice(&t.next_state);
        }
        // Double-DQN target: a* from the online net, value from the target.
        let q_next_online = self.q.forward(&s2);
        let q_next_target = self.q_target.forward(&s2);
        let cache = self.q.forward_cache(&s);
        let qs = cache.output();

        // Build per-sample prediction/target (selected action only) and use
        // Huber for a clipped gradient.
        let mut pred = Mat::zeros(n, 1);
        let mut tgt = Mat::zeros(n, 1);
        for i in 0..n {
            let a_star = Self::argmax_row(&q_next_online, i);
            let t = &batch[i];
            let y = t.reward
                + self.cfg.gamma
                    * if t.done { 0.0 } else { q_next_target.at(i, a_star) };
            *pred.at_mut(i, 0) = qs.at(i, t.action);
            *tgt.at_mut(i, 0) = y;
        }
        let (loss, dpred) = huber(&pred, &tgt, 1.0);
        // Scatter the per-sample gradient back onto the taken actions.
        let mut d = Mat::zeros(n, self.n_actions);
        for i in 0..n {
            *d.at_mut(i, batch[i].action) = dpred.at(i, 0);
        }
        let grads = self.q.backward(&cache, &d);
        self.opt.step(&mut self.q, &grads);

        self.updates += 1;
        if self.updates % self.cfg.target_sync == 0 {
            self.q_target = self.q.clone();
        }
        loss
    }

    fn name(&self) -> &'static str {
        "DDQN"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::env::testenv::Chain;
    use crate::rl::env::{train_episodes, Env};

    #[test]
    fn epsilon_decays() {
        let mut rng = Pcg32::seeded(51);
        let mut agent = Ddqn::new(4, 2, DdqnConfig::default(), &mut rng);
        let e0 = agent.epsilon();
        agent.steps = agent.cfg.eps_decay_steps;
        assert!(e0 > agent.epsilon());
        assert!((agent.epsilon() - agent.cfg.eps_end).abs() < 1e-6);
    }

    #[test]
    fn learns_chain_mdp() {
        let mut rng = Pcg32::seeded(52);
        let mut env = Chain::new(5);
        let cfg = DdqnConfig {
            warmup: 32,
            batch_size: 32,
            eps_decay_steps: 400,
            lr: 3e-3,
            ..Default::default()
        };
        let mut agent =
            Ddqn::new(env.state_dim(), env.n_actions(), cfg, &mut rng);
        let hist = train_episodes(&mut env, &mut agent, 80, 30, &mut rng);
        let late: f32 =
            hist[hist.len() - 10..].iter().map(|x| x.0).sum::<f32>() / 10.0;
        assert!(late > 0.7, "did not learn chain: late return {late}");
    }
}
