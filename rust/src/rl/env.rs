//! MDP interface shared by the scheduling environment and every agent.

use crate::util::rng::Pcg32;

/// One (s, a, r, s', done) tuple — what the replay buffer stores
//  (paper Algorithm 1, line 11).
#[derive(Clone, Debug)]
pub struct Transition {
    pub state: Vec<f32>,
    pub action: usize,
    pub reward: f32,
    pub next_state: Vec<f32>,
    pub done: bool,
}

/// Result of one environment step.
#[derive(Clone, Debug)]
pub struct Step {
    pub next_state: Vec<f32>,
    pub reward: f32,
    pub done: bool,
}

/// A discrete-action MDP. The scheduling environment
/// (`coordinator::sac_sched::SchedEnv`) implements this over the platform
/// simulator; toy envs in tests implement it directly.
pub trait Env {
    fn state_dim(&self) -> usize;
    fn n_actions(&self) -> usize;
    fn reset(&mut self, rng: &mut Pcg32) -> Vec<f32>;
    fn step(&mut self, action: usize, rng: &mut Pcg32) -> Step;
}

/// A learning agent over a discrete action space.
pub trait Agent {
    /// Choose an action. `greedy` disables exploration (deployment mode —
    /// the paper trains offline and deploys the trained policy online).
    fn act(&mut self, state: &[f32], rng: &mut Pcg32, greedy: bool) -> usize;

    /// Record a transition (on-policy agents may also update here).
    fn observe(&mut self, t: Transition);

    /// One gradient/update step; returns the training loss for Fig. 10.
    fn update(&mut self, rng: &mut Pcg32) -> f32;

    /// Human-readable name for bench tables.
    fn name(&self) -> &'static str;
}

/// Run `episodes` episodes of `agent` on `env`, updating after every step;
/// returns per-episode (return, mean loss). Shared by the Fig. 10 bench
/// and the offline training driver.
pub fn train_episodes<E: Env, A: Agent + ?Sized>(
    env: &mut E,
    agent: &mut A,
    episodes: usize,
    max_steps: usize,
    rng: &mut Pcg32,
) -> Vec<(f32, f32)> {
    let mut out = Vec::with_capacity(episodes);
    for _ in 0..episodes {
        let mut state = env.reset(rng);
        let mut ret = 0.0;
        let mut losses = 0.0;
        let mut n_loss = 0;
        for step in 0..max_steps {
            let action = agent.act(&state, rng, false);
            let s = env.step(action, rng);
            let done = s.done || step + 1 == max_steps;
            agent.observe(Transition {
                state: state.clone(),
                action,
                reward: s.reward,
                next_state: s.next_state.clone(),
                done,
            });
            ret += s.reward;
            let loss = agent.update(rng);
            if loss.is_finite() && loss != 0.0 {
                losses += loss;
                n_loss += 1;
            }
            state = s.next_state;
            if done {
                break;
            }
        }
        out.push((ret, if n_loss > 0 { losses / n_loss as f32 } else { 0.0 }));
    }
    out
}

#[cfg(test)]
pub mod testenv {
    use super::*;

    /// A tiny deterministic chain MDP for agent sanity tests: states
    /// 0..n-1, action 1 moves right (+1 reward at the end), action 0
    /// stays (0 reward). Optimal return = 1.0 within n steps.
    pub struct Chain {
        pub n: usize,
        pos: usize,
    }

    impl Chain {
        pub fn new(n: usize) -> Self {
            Chain { n, pos: 0 }
        }

        fn encode(&self) -> Vec<f32> {
            let mut v = vec![0.0; self.n];
            v[self.pos] = 1.0;
            v
        }
    }

    impl Env for Chain {
        fn state_dim(&self) -> usize {
            self.n
        }

        fn n_actions(&self) -> usize {
            2
        }

        fn reset(&mut self, _rng: &mut Pcg32) -> Vec<f32> {
            self.pos = 0;
            self.encode()
        }

        fn step(&mut self, action: usize, _rng: &mut Pcg32) -> Step {
            if action == 1 && self.pos + 1 < self.n {
                self.pos += 1;
            }
            let done = self.pos + 1 == self.n;
            Step {
                next_state: self.encode(),
                reward: if done { 1.0 } else { -0.01 },
                done,
            }
        }
    }
}
