//! Genetic-algorithm baseline (paper §V-B, ref [43]): "survival of the
//! fittest" search with the BCEdge utility as the fitness function.
//!
//! The GA evolves a *linear policy* (state → action scores) by tournament
//! selection, uniform crossover, and Gaussian mutation; fitness is the
//! mean episode return. The paper observes GA is premature (local optima)
//! and pays heavy crossover/mutation compute — both properties fall out of
//! this implementation and are visible in the Fig. 10 bench.

use super::env::{Agent, Env, Transition};
use crate::util::rng::Pcg32;

/// GA hyper-parameters.
#[derive(Clone, Debug)]
pub struct GaConfig {
    pub population: usize,
    pub tournament: usize,
    pub mutation_rate: f64,
    pub mutation_std: f32,
    pub elite: usize,
    /// Episodes per fitness evaluation.
    pub eval_episodes: usize,
    pub max_steps: usize,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            population: 32,
            tournament: 4,
            mutation_rate: 0.1,
            mutation_std: 0.3,
            elite: 2,
            eval_episodes: 2,
            max_steps: 64,
        }
    }
}

/// A genome: a flat (state_dim × n_actions) score matrix.
#[derive(Clone)]
struct Genome {
    w: Vec<f32>,
    fitness: f32,
}

/// Evolutionary policy search over linear policies.
pub struct Ga {
    cfg: GaConfig,
    state_dim: usize,
    n_actions: usize,
    population: Vec<Genome>,
    best: Genome,
    generations: usize,
}

impl Ga {
    pub fn new(state_dim: usize, n_actions: usize, cfg: GaConfig,
               rng: &mut Pcg32) -> Self {
        let population: Vec<Genome> = (0..cfg.population)
            .map(|_| Genome {
                w: (0..state_dim * n_actions)
                    .map(|_| (rng.f32() * 2.0 - 1.0) * 0.5)
                    .collect(),
                fitness: f32::NEG_INFINITY,
            })
            .collect();
        let best = population[0].clone();
        Ga { cfg, state_dim, n_actions, population, best, generations: 0 }
    }

    fn action_of(&self, genome: &Genome, state: &[f32]) -> usize {
        let mut best = 0;
        let mut best_score = f32::NEG_INFINITY;
        for a in 0..self.n_actions {
            let mut score = 0.0;
            for (i, &s) in state.iter().enumerate() {
                score += s * genome.w[i * self.n_actions + a];
            }
            if score > best_score {
                best_score = score;
                best = a;
            }
        }
        best
    }

    fn evaluate<E: Env>(&self, genome: &Genome, env: &mut E,
                        rng: &mut Pcg32) -> f32 {
        let mut total = 0.0;
        for _ in 0..self.cfg.eval_episodes {
            let mut state = env.reset(rng);
            for _ in 0..self.cfg.max_steps {
                let a = self.action_of(genome, &state);
                let s = env.step(a, rng);
                total += s.reward;
                state = s.next_state;
                if s.done {
                    break;
                }
            }
        }
        total / self.cfg.eval_episodes as f32
    }

    /// One generation of evolution against `env`. Returns the loss proxy
    /// for Fig. 10 (negative best fitness, so "lower is better" like the
    /// DRL losses).
    pub fn evolve<E: Env>(&mut self, env: &mut E, rng: &mut Pcg32) -> f32 {
        // Fitness evaluation — the expensive part the paper calls out
        // ("GA involves a large number of calculations").
        for i in 0..self.population.len() {
            let f = self.evaluate(&self.population[i], env, rng);
            self.population[i].fitness = f;
        }
        self.population
            .sort_by(|a, b| b.fitness.partial_cmp(&a.fitness).unwrap());
        if self.population[0].fitness > self.best.fitness {
            self.best = self.population[0].clone();
        }

        // Next generation: elitism + tournament parents + uniform
        // crossover + Gaussian mutation.
        let mut next: Vec<Genome> =
            self.population[..self.cfg.elite].to_vec();
        while next.len() < self.cfg.population {
            let p1 = self.tournament_pick(rng);
            let p2 = self.tournament_pick(rng);
            let mut child = vec![0.0f32; self.state_dim * self.n_actions];
            for (i, c) in child.iter_mut().enumerate() {
                *c = if rng.f32() < 0.5 { p1.w[i] } else { p2.w[i] };
                if rng.f64() < self.cfg.mutation_rate {
                    *c += rng.normal() as f32 * self.cfg.mutation_std;
                }
            }
            next.push(Genome { w: child, fitness: f32::NEG_INFINITY });
        }
        self.population = next;
        self.generations += 1;
        -self.best.fitness
    }

    fn tournament_pick(&self, rng: &mut Pcg32) -> &Genome {
        let mut best: Option<&Genome> = None;
        for _ in 0..self.cfg.tournament {
            let cand =
                &self.population[rng.below(self.population.len() as u32) as usize];
            if best.map(|b| cand.fitness > b.fitness).unwrap_or(true) {
                best = Some(cand);
            }
        }
        best.unwrap()
    }

    pub fn best_fitness(&self) -> f32 {
        self.best.fitness
    }
}

/// Adapter so the GA's *deployed* best policy can serve as an [`Agent`]
/// (act = best genome's argmax; observe/update are no-ops because
/// evolution happens generation-wise via [`Ga::evolve`]).
impl Agent for Ga {
    fn act(&mut self, state: &[f32], _rng: &mut Pcg32, _greedy: bool) -> usize {
        let best = self.best.clone();
        self.action_of(&best, state)
    }

    fn observe(&mut self, _t: Transition) {}

    fn update(&mut self, _rng: &mut Pcg32) -> f32 {
        0.0
    }

    fn name(&self) -> &'static str {
        "GA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::env::testenv::Chain;

    #[test]
    fn evolution_improves_fitness() {
        let mut rng = Pcg32::seeded(81);
        let mut env = Chain::new(5);
        let mut ga = Ga::new(5, 2, GaConfig::default(), &mut rng);
        ga.evolve(&mut env, &mut rng);
        let first = ga.best_fitness();
        for _ in 0..10 {
            ga.evolve(&mut env, &mut rng);
        }
        assert!(ga.best_fitness() >= first);
        // Chain(5) is solvable by a linear policy: expect near-optimal.
        assert!(ga.best_fitness() > 0.8, "fitness {}", ga.best_fitness());
    }

    #[test]
    fn elite_preserved() {
        let mut rng = Pcg32::seeded(82);
        let mut env = Chain::new(4);
        let mut ga = Ga::new(4, 2, GaConfig::default(), &mut rng);
        let mut last = f32::NEG_INFINITY;
        for _ in 0..5 {
            ga.evolve(&mut env, &mut rng);
            assert!(ga.best_fitness() >= last);
            last = ga.best_fitness();
        }
    }
}
