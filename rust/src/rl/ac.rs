//! Vanilla actor-critic WITHOUT entropy regularization — the "TAC"
//! baseline of paper §V-B ("we combine Triton with Actor-Critic without
//! entropy to compare with BCEdge"). One-step TD advantage, on-policy.

use super::env::{Agent, Transition};
use crate::nn::adam::Adam;
use crate::nn::tensor::{softmax_rows, Mat};
use crate::nn::Mlp;
use crate::util::rng::Pcg32;

/// Hyper-parameters.
#[derive(Clone, Debug)]
pub struct AcConfig {
    pub hidden: Vec<usize>,
    pub lr: f32,
    pub gamma: f32,
}

impl Default for AcConfig {
    fn default() -> Self {
        AcConfig { hidden: vec![128, 64], lr: 1e-3, gamma: 0.99 }
    }
}

/// On-policy actor-critic (no entropy bonus — the point of the baseline).
pub struct ActorCritic {
    cfg: AcConfig,
    n_actions: usize,
    actor: Mlp,
    critic: Mlp,
    opt_actor: Adam,
    opt_critic: Adam,
    pending: Option<Transition>,
}

impl ActorCritic {
    pub fn new(state_dim: usize, n_actions: usize, cfg: AcConfig,
               rng: &mut Pcg32) -> Self {
        let mut pi_sizes = vec![state_dim];
        pi_sizes.extend(&cfg.hidden);
        pi_sizes.push(n_actions);
        let mut v_sizes = vec![state_dim];
        v_sizes.extend(&cfg.hidden);
        v_sizes.push(1);
        let actor = Mlp::new(&pi_sizes, rng);
        let critic = Mlp::new(&v_sizes, rng);
        let opt_actor = Adam::new(&actor, cfg.lr);
        let opt_critic = Adam::new(&critic, cfg.lr);
        ActorCritic {
            cfg,
            n_actions,
            actor,
            critic,
            opt_actor,
            opt_critic,
            pending: None,
        }
    }

    pub fn policy_probs(&self, state: &[f32]) -> Vec<f32> {
        softmax_rows(&self.actor.forward(&Mat::row_vec(state)))
            .row(0)
            .to_vec()
    }
}

impl Agent for ActorCritic {
    fn act(&mut self, state: &[f32], rng: &mut Pcg32, greedy: bool) -> usize {
        let probs = self.policy_probs(state);
        if greedy {
            probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        } else {
            rng.categorical(&probs.iter().map(|&p| p as f64).collect::<Vec<_>>())
        }
    }

    fn observe(&mut self, t: Transition) {
        self.pending = Some(t);
    }

    fn update(&mut self, _rng: &mut Pcg32) -> f32 {
        let Some(t) = self.pending.take() else { return 0.0 };
        let s = Mat::row_vec(&t.state);
        let s2 = Mat::row_vec(&t.next_state);

        // Critic: TD(0) target.
        let v_next = if t.done { 0.0 } else { self.critic.forward(&s2).at(0, 0) };
        let target = t.reward + self.cfg.gamma * v_next;
        let cache_v = self.critic.forward_cache(&s);
        let v = cache_v.output().at(0, 0);
        let advantage = target - v;
        let dv = Mat::from_vec(1, 1, vec![2.0 * (v - target)]);
        let grads_v = self.critic.backward(&cache_v, &dv);
        self.opt_critic.step(&mut self.critic, &grads_v);

        // Actor: policy-gradient step on −A·log π(a|s).
        // ∂(−A log π_a)/∂z_k = A (π_k − δ_ak)
        let cache_pi = self.actor.forward_cache(&s);
        let pi = softmax_rows(cache_pi.output());
        let mut d = Mat::zeros(1, self.n_actions);
        for k in 0..self.n_actions {
            let delta = if k == t.action { 1.0 } else { 0.0 };
            *d.at_mut(0, k) = advantage * (pi.at(0, k) - delta);
        }
        let grads_pi = self.actor.backward(&cache_pi, &d);
        self.opt_actor.step(&mut self.actor, &grads_pi);

        // Report the critic TD error as the training loss (Fig. 10 series).
        (v - target) * (v - target)
    }

    fn name(&self) -> &'static str {
        "TAC (actor-critic, no entropy)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::env::testenv::Chain;
    use crate::rl::env::{train_episodes, Env};

    #[test]
    fn learns_chain_mdp() {
        let mut rng = Pcg32::seeded(61);
        let mut env = Chain::new(3);
        let mut agent = ActorCritic::new(
            env.state_dim(),
            env.n_actions(),
            AcConfig { lr: 5e-3, ..Default::default() },
            &mut rng,
        );
        let hist = train_episodes(&mut env, &mut agent, 300, 25, &mut rng);
        let late: f32 =
            hist[hist.len() - 20..].iter().map(|x| x.0).sum::<f32>() / 20.0;
        assert!(late > 0.6, "did not learn chain: late return {late}");
    }

    #[test]
    fn update_without_observe_is_noop() {
        let mut rng = Pcg32::seeded(62);
        let mut agent = ActorCritic::new(3, 2, AcConfig::default(), &mut rng);
        assert_eq!(agent.update(&mut rng), 0.0);
    }
}
