//! Edge platform specifications (paper Table III and Table V).

/// Static description of an edge platform.
#[derive(Clone, Debug, PartialEq)]
pub struct PlatformSpec {
    pub name: &'static str,
    /// Relative compute throughput, Xavier NX ≡ 1.0 (derived from Table V:
    /// Nano 0.47 TFLOPS FP16 / TX2 1.33 TFLOPS FP16 / NX 21 TOPS INT8 ≈
    /// ~5.9 TFLOPS-FP16-equivalent at the paper's INT8/TensorRT operating
    /// point).
    pub compute_scale: f64,
    /// RAM available to the serving runtime, MB (Table V, minus ~1.5 GB
    /// OS/runtime reserve measured on Jetson boards).
    pub memory_mb: f64,
    /// CUDA-core count (Table V) — drives the contention knee of the
    /// interference model: more cores tolerate more concurrency.
    pub cuda_cores: usize,
    /// Hard cap on concurrent model instances the runtime will allow.
    pub max_instances: usize,
}

impl PlatformSpec {
    /// NVIDIA Jetson Xavier NX — the paper's primary platform (Table III).
    pub fn xavier_nx() -> Self {
        PlatformSpec {
            name: "Xavier NX",
            compute_scale: 1.0,
            memory_mb: 6500.0, // 8 GB − OS reserve
            cuda_cores: 384,
            max_instances: 8,
        }
    }

    /// NVIDIA Jetson TX2 (Table V).
    pub fn jetson_tx2() -> Self {
        PlatformSpec {
            name: "Jetson TX2",
            compute_scale: 1.33 / 5.9, // FP16 TFLOPS ratio vs NX-equivalent
            memory_mb: 6500.0,
            cuda_cores: 256,
            max_instances: 6,
        }
    }

    /// NVIDIA Jetson Nano (Table V).
    pub fn jetson_nano() -> Self {
        PlatformSpec {
            name: "Jetson Nano",
            compute_scale: 0.47 / 5.9,
            memory_mb: 2500.0, // 4 GB − OS reserve
            cuda_cores: 128,
            max_instances: 4,
        }
    }

    /// The host CPU running the real PJRT backend; compute_scale is
    /// calibrated at runtime (`LatencyModel::calibrate`).
    pub fn host_cpu() -> Self {
        PlatformSpec {
            name: "Host CPU (PJRT)",
            compute_scale: 1.0,
            memory_mb: 8000.0,
            cuda_cores: 384,
            max_instances: 8,
        }
    }

    /// The Fig. 11/12 sweep set, weakest first.
    pub fn scalability_set() -> Vec<PlatformSpec> {
        vec![Self::jetson_nano(), Self::jetson_tx2(), Self::xavier_nx()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_table_v() {
        let nano = PlatformSpec::jetson_nano();
        let tx2 = PlatformSpec::jetson_tx2();
        let nx = PlatformSpec::xavier_nx();
        assert!(nano.compute_scale < tx2.compute_scale);
        assert!(tx2.compute_scale < nx.compute_scale);
        assert!(nano.memory_mb < nx.memory_mb);
        assert!(nano.cuda_cores < tx2.cuda_cores);
        assert!(tx2.cuda_cores < nx.cuda_cores);
    }

    #[test]
    fn scalability_set_is_weakest_first() {
        let set = PlatformSpec::scalability_set();
        assert_eq!(set.len(), 3);
        assert!(set.windows(2).all(|w| w[0].compute_scale < w[1].compute_scale));
    }
}
