//! Isolated-execution latency model: L₀(model, batch) with no
//! interference.
//!
//! Shape: one batched inference costs a fixed dispatch/setup term plus a
//! per-sample compute term with a mild batching economy (per-sample cost
//! decays toward an asymptote as the batch fills the accelerator — the
//! same curve TensorRT engines show on Jetson and that Fig. 1 relies on:
//! throughput rises with batch, then flattens, while latency keeps
//! growing).
//!
//!   L₀(m, b) = (setup_ms + per_sample_ms · b · e(b)) / compute_scale
//!   e(b)     = floor + (1 − floor) / b^economy   (amortization factor)
//!
//! Default constants are calibrated from real PJRT CPU measurements of the
//! AOT artifacts (see `examples/quickstart.rs --calibrate` and
//! EXPERIMENTS.md §Calibration); per-model ratios track the zoo's
//! heterogeneity.

use crate::workload::models::{ModelId, N_MODELS};

/// Per-model latency constants.
#[derive(Clone, Copy, Debug)]
pub struct ModelLatency {
    /// Fixed per-batch dispatch + setup cost, ms (at compute_scale 1.0).
    pub setup_ms: f64,
    /// Asymptotic per-sample compute cost, ms.
    pub per_sample_ms: f64,
    /// Batching-economy exponent in (0, 1]; higher = stronger economy.
    pub economy: f64,
}

impl ModelLatency {
    /// Isolated latency of one batch of `b` samples (compute_scale 1.0).
    pub fn batch_ms(&self, b: usize) -> f64 {
        assert!(b > 0);
        let floor = 0.6;
        let e = floor + (1.0 - floor) / (b as f64).powf(self.economy);
        self.setup_ms + self.per_sample_ms * b as f64 * e
    }
}

/// Full zoo latency model on a given platform compute scale.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    per_model: [ModelLatency; N_MODELS],
    compute_scale: f64,
}

impl LatencyModel {
    /// Calibrated defaults (ms at Xavier-NX-equivalent scale 1.0).
    /// Ratios between models follow measured PJRT batch-1 latencies of the
    /// AOT artifacts; absolute values are scaled to Jetson-class
    /// magnitudes so each model's batch-1 latency sits at ~20–40 % of its
    /// paper SLO. That head-room ratio is what makes scheduling
    /// non-trivial at the paper's 30 rps: queues build under bursts, so
    /// batch size and concurrency genuinely move the utility (Fig. 7).
    pub fn calibrated() -> Self {
        use ModelId::*;
        let mut per_model = [ModelLatency {
            setup_ms: 4.0,
            per_sample_ms: 4.0,
            economy: 0.35,
        }; N_MODELS];
        // (setup, per_sample, economy) — yolo heaviest, mob lightest.
        per_model[Yolo as usize] =
            ModelLatency { setup_ms: 24.0, per_sample_ms: 20.8, economy: 0.38 };
        per_model[Mob as usize] =
            ModelLatency { setup_ms: 8.8, per_sample_ms: 6.4, economy: 0.42 };
        per_model[Res as usize] =
            ModelLatency { setup_ms: 12.0, per_sample_ms: 9.6, economy: 0.40 };
        per_model[Eff as usize] =
            ModelLatency { setup_ms: 11.2, per_sample_ms: 8.0, economy: 0.40 };
        per_model[Inc as usize] =
            ModelLatency { setup_ms: 13.6, per_sample_ms: 8.8, economy: 0.37 };
        per_model[Bert as usize] =
            ModelLatency { setup_ms: 16.8, per_sample_ms: 12.0, economy: 0.45 };
        LatencyModel { per_model, compute_scale: 1.0 }
    }

    /// Same table rescaled for a platform (Nano/TX2 sweeps).
    pub fn with_compute_scale(mut self, scale: f64) -> Self {
        assert!(scale > 0.0);
        self.compute_scale = scale;
        self
    }

    /// Override one model's constants (used by runtime calibration).
    pub fn set_model(&mut self, model: ModelId, lat: ModelLatency) {
        self.per_model[model as usize] = lat;
    }

    pub fn model(&self, model: ModelId) -> &ModelLatency {
        &self.per_model[model as usize]
    }

    /// Isolated batch latency on this platform, ms.
    pub fn isolated_ms(&self, model: ModelId, batch: usize) -> f64 {
        self.per_model[model as usize].batch_ms(batch) / self.compute_scale
    }

    /// Isolated throughput, requests/s, for a back-to-back batch stream.
    pub fn isolated_rps(&self, model: ModelId, batch: usize) -> f64 {
        batch as f64 / self.isolated_ms(model, batch) * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_monotone_in_batch() {
        let m = LatencyModel::calibrated();
        for model in ModelId::all() {
            let mut prev = 0.0;
            for b in [1, 2, 4, 8, 16, 32, 64, 128] {
                let l = m.isolated_ms(model, b);
                assert!(l > prev, "{model:?} b={b}: {l} <= {prev}");
                prev = l;
            }
        }
    }

    #[test]
    fn throughput_improves_with_batch_then_saturates() {
        // The Fig. 1 premise: batching gains are large early, marginal late.
        let m = LatencyModel::calibrated();
        let t1 = m.isolated_rps(ModelId::Yolo, 1);
        let t8 = m.isolated_rps(ModelId::Yolo, 8);
        let t64 = m.isolated_rps(ModelId::Yolo, 64);
        let t128 = m.isolated_rps(ModelId::Yolo, 128);
        assert!(t8 > 1.5 * t1, "early batching gain missing: {t1} → {t8}");
        let late_gain = t128 / t64;
        assert!(late_gain < 1.15, "late gain should be marginal: {late_gain}");
    }

    #[test]
    fn batch1_latency_within_slo_headroom() {
        // Scheduling is only interesting if isolated batch-1 latency is
        // well inside the SLO (20–60 %).
        use crate::workload::models::ModelSpec;
        let m = LatencyModel::calibrated();
        for model in ModelId::all() {
            let slo = ModelSpec::get(model).slo_ms;
            let l1 = m.isolated_ms(model, 1);
            assert!(l1 > 0.03 * slo && l1 < 0.6 * slo,
                    "{model:?}: batch-1 {l1} ms vs SLO {slo} ms");
        }
    }

    #[test]
    fn compute_scale_slows_platform() {
        let nx = LatencyModel::calibrated();
        let nano = LatencyModel::calibrated().with_compute_scale(0.08);
        assert!(nano.isolated_ms(ModelId::Res, 4) > 5.0 * nx.isolated_ms(ModelId::Res, 4));
    }
}
