//! Ground-truth interference model for the simulator (§IV-F's adversary).
//!
//! When multiple model instances execute concurrently they contend for
//! compute (SM/core occupancy) and memory bandwidth. The paper's Fig. 1
//! shows the empirical signature on Xavier NX: mild slowdown at low
//! concurrency, then a superlinear blow-up as the board saturates, and
//! outright failure (OOM) at extreme (batch × instances). We model latency
//! inflation as a product of two nonlinear terms:
//!
//!   inflate = (1 + k_c · max(0, load − 1)^p) · (1 + k_m · σ((pressure − m₀)/s))
//!
//! where `load` = active-instance compute demand / platform capacity,
//! `pressure` = memory-pool utilization, and σ is a logistic. The
//! *nonlinearity is the point*: the paper shows a linear-regression
//! predictor fits this badly (Fig. 13), and our NN predictor must beat it
//! for the same reason.

use super::spec::PlatformSpec;

/// Tunable interference constants.
#[derive(Clone, Copy, Debug)]
pub struct InterferenceModel {
    /// Compute-contention gain.
    pub k_compute: f64,
    /// Contention exponent (> 1 ⇒ superlinear, per Fig. 1).
    pub p_compute: f64,
    /// Memory-bandwidth gain.
    pub k_memory: f64,
    /// Logistic midpoint of memory pressure.
    pub m0: f64,
    /// Logistic steepness.
    pub steep: f64,
}

impl Default for InterferenceModel {
    fn default() -> Self {
        InterferenceModel {
            k_compute: 0.55,
            p_compute: 1.6,
            k_memory: 1.2,
            m0: 0.75,
            steep: 0.08,
        }
    }
}

/// Instantaneous system load seen by one executing batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct SystemLoad {
    /// Total concurrently-active instances (including self).
    pub active_instances: usize,
    /// Sum of active instances' normalized compute demand (1.0 = one
    /// average instance fully occupying the accelerator).
    pub compute_demand: f64,
    /// Memory-pool utilization in [0, 1].
    pub memory_pressure: f64,
}

impl InterferenceModel {
    /// Latency inflation factor ≥ 1 for a batch executing under `load` on
    /// `platform`.
    pub fn inflation(&self, load: &SystemLoad, platform: &PlatformSpec) -> f64 {
        // Capacity: how much parallel instance demand the board absorbs
        // before contention begins. Scales with core count (Table V) —
        // Nano's 128 cores saturate earlier than NX's 384.
        let capacity = platform.cuda_cores as f64 / 384.0 * 2.0;
        let overload = (load.compute_demand / capacity - 1.0).max(0.0);
        let compute_term = 1.0 + self.k_compute * overload.powf(self.p_compute);
        let z = (load.memory_pressure - self.m0) / self.steep;
        let sigma = 1.0 / (1.0 + (-z).exp());
        let memory_term = 1.0 + self.k_memory * sigma;
        compute_term * memory_term
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nx() -> PlatformSpec {
        PlatformSpec::xavier_nx()
    }

    #[test]
    fn no_load_no_inflation() {
        let m = InterferenceModel::default();
        let load = SystemLoad {
            active_instances: 1,
            compute_demand: 0.5,
            memory_pressure: 0.1,
        };
        let f = m.inflation(&load, &nx());
        assert!(f < 1.02, "idle inflation {f}");
    }

    #[test]
    fn inflation_superlinear_in_compute_demand() {
        let m = InterferenceModel::default();
        let f = |d: f64| {
            m.inflation(
                &SystemLoad {
                    active_instances: 4,
                    compute_demand: d,
                    memory_pressure: 0.2,
                },
                &nx(),
            )
        };
        let g1 = f(3.0) - f(2.5);
        let g2 = f(5.0) - f(4.5);
        assert!(g2 > g1, "not superlinear: {g1} vs {g2}");
    }

    #[test]
    fn memory_pressure_kicks_in_late() {
        let m = InterferenceModel::default();
        let f = |p: f64| {
            m.inflation(
                &SystemLoad {
                    active_instances: 2,
                    compute_demand: 1.0,
                    memory_pressure: p,
                },
                &nx(),
            )
        };
        assert!(f(0.3) < 1.1);          // plenty of head-room
        assert!(f(0.95) > 1.8);         // near-OOM thrashing
        assert!(f(0.95) > f(0.6));
    }

    #[test]
    fn weaker_platform_saturates_earlier() {
        let m = InterferenceModel::default();
        let load = SystemLoad {
            active_instances: 4,
            compute_demand: 2.5,
            memory_pressure: 0.3,
        };
        let on_nx = m.inflation(&load, &PlatformSpec::xavier_nx());
        let on_nano = m.inflation(&load, &PlatformSpec::jetson_nano());
        assert!(on_nano > on_nx, "nano {on_nano} vs nx {on_nx}");
    }

    #[test]
    fn interference_is_nonlinear_in_inputs() {
        // Sanity for Fig. 13: a plane cannot fit this surface well. Check
        // that the mixed second difference is non-zero.
        let m = InterferenceModel::default();
        let f = |d: f64, p: f64| {
            m.inflation(
                &SystemLoad {
                    active_instances: 3,
                    compute_demand: d,
                    memory_pressure: p,
                },
                &nx(),
            )
        };
        let mixed = f(4.0, 0.9) - f(4.0, 0.4) - f(2.0, 0.9) + f(2.0, 0.4);
        assert!(mixed.abs() > 0.05, "surface looks planar: {mixed}");
    }
}
