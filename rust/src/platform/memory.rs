//! Memory accounting for concurrent batched inference.
//!
//! The Eq. (4) constraint `m_i ≤ M_i` and the Fig. 1 memory-overflow
//! corner both live here: each (model, batch, instances) combination
//! demands weights × instances + activations × batch × instances, and a
//! reservation that exceeds the pool fails like the Jetson OOM does.

use std::collections::BTreeMap;

/// Memory demand descriptor for one model configuration.
#[derive(Clone, Copy, Debug)]
pub struct MemoryDemand {
    /// Per-instance weight footprint, MB (TensorRT engine analogue:
    /// weights + workspace).
    pub weights_mb: f64,
    /// Per-sample activation footprint, MB.
    pub activation_mb_per_sample: f64,
}

impl MemoryDemand {
    /// Total MB for `instances` instances each running batch `b`.
    pub fn total_mb(&self, batch: usize, instances: usize) -> f64 {
        instances as f64
            * (self.weights_mb + self.activation_mb_per_sample * batch as f64)
    }
}

/// Tracked reservation pool for a platform's RAM.
#[derive(Clone, Debug)]
pub struct MemoryPool {
    capacity_mb: f64,
    reservations: BTreeMap<u64, f64>,
    next_id: u64,
    used_mb: f64,
    /// Peak usage watermark (reported by the profiler).
    peak_mb: f64,
}

/// Error returned when a reservation would overflow the pool.
#[derive(Clone, Copy, Debug, PartialEq, thiserror::Error)]
#[error("out of memory: requested {requested_mb:.1} MB, free {free_mb:.1} MB of {capacity_mb:.1} MB")]
pub struct OomError {
    pub requested_mb: f64,
    pub free_mb: f64,
    pub capacity_mb: f64,
}

impl MemoryPool {
    pub fn new(capacity_mb: f64) -> Self {
        assert!(capacity_mb > 0.0);
        MemoryPool {
            capacity_mb,
            reservations: BTreeMap::new(),
            next_id: 0,
            used_mb: 0.0,
            peak_mb: 0.0,
        }
    }

    pub fn capacity_mb(&self) -> f64 {
        self.capacity_mb
    }

    pub fn used_mb(&self) -> f64 {
        self.used_mb
    }

    pub fn free_mb(&self) -> f64 {
        self.capacity_mb - self.used_mb
    }

    pub fn peak_mb(&self) -> f64 {
        self.peak_mb
    }

    /// Utilization in [0, 1].
    pub fn pressure(&self) -> f64 {
        self.used_mb / self.capacity_mb
    }

    /// Reserve `mb`; returns a ticket to release later.
    pub fn reserve(&mut self, mb: f64) -> Result<u64, OomError> {
        assert!(mb >= 0.0);
        if self.used_mb + mb > self.capacity_mb {
            return Err(OomError {
                requested_mb: mb,
                free_mb: self.free_mb(),
                capacity_mb: self.capacity_mb,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.reservations.insert(id, mb);
        self.used_mb += mb;
        self.peak_mb = self.peak_mb.max(self.used_mb);
        Ok(id)
    }

    /// Release a ticket; idempotent (double release is a no-op).
    pub fn release(&mut self, ticket: u64) {
        if let Some(mb) = self.reservations.remove(&ticket) {
            self.used_mb = (self.used_mb - mb).max(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_release_cycle() {
        let mut pool = MemoryPool::new(100.0);
        let a = pool.reserve(40.0).unwrap();
        let b = pool.reserve(50.0).unwrap();
        assert!((pool.used_mb() - 90.0).abs() < 1e-9);
        assert!(pool.reserve(20.0).is_err()); // would overflow
        pool.release(a);
        assert!(pool.reserve(20.0).is_ok());
        pool.release(b);
        assert!(pool.peak_mb() >= 90.0);
    }

    #[test]
    fn double_release_is_noop() {
        let mut pool = MemoryPool::new(10.0);
        let t = pool.reserve(5.0).unwrap();
        pool.release(t);
        pool.release(t);
        assert_eq!(pool.used_mb(), 0.0);
    }

    #[test]
    fn oom_error_reports_numbers() {
        let mut pool = MemoryPool::new(10.0);
        pool.reserve(8.0).unwrap();
        let e = pool.reserve(5.0).unwrap_err();
        assert!((e.free_mb - 2.0).abs() < 1e-9);
        assert_eq!(e.capacity_mb, 10.0);
    }

    #[test]
    fn demand_scales_with_batch_and_instances() {
        let d = MemoryDemand { weights_mb: 100.0, activation_mb_per_sample: 2.0 };
        assert_eq!(d.total_mb(1, 1), 102.0);
        assert_eq!(d.total_mb(8, 1), 116.0);
        assert_eq!(d.total_mb(8, 4), 464.0);
    }
}
