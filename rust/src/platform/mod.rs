//! Simulated edge platform: hardware specs (paper Tables III/V), memory
//! accounting, a calibrated latency model, and the nonlinear ground-truth
//! interference model that the §IV-F predictor has to learn.
//!
//! Why a simulator exists at all (DESIGN.md §4): the paper's testbed is a
//! trio of NVIDIA Jetson boards. The *real* execution path in this repo
//! (PJRT CPU) preserves the mechanism end-to-end, but platform scalability
//! (Figs. 11/12), 3000-second horizons (Figs. 8/9/14), and deliberate
//! memory-overflow corners (Fig. 1) need a platform model that can run in
//! virtual time and be swept across hardware configs. The latency table is
//! calibrated against real PJRT measurements (see `latency`).

pub mod interference;
pub mod latency;
pub mod memory;
pub mod sim;
pub mod spec;

pub use interference::InterferenceModel;
pub use latency::LatencyModel;
pub use memory::{MemoryDemand, MemoryPool, OomError};
pub use sim::PlatformSim;
pub use spec::PlatformSpec;
