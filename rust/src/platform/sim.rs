//! The composed platform simulator: spec + memory pool + latency model +
//! interference model, with begin/end bookkeeping for concurrently
//! executing batches.
//!
//! The serving engine drives this in virtual time: `begin` reserves memory
//! and registers compute demand (failing like a Jetson OOM when the pool
//! is exhausted — Eq. 4's m_i ≤ M_i), `duration_ms` prices a batch under
//! the *current* contention, and `end` releases resources. Cross-model
//! interference emerges naturally from overlapping begin/end windows.

use super::interference::{InterferenceModel, SystemLoad};
use super::latency::LatencyModel;
use super::memory::{MemoryPool, OomError};
use super::spec::PlatformSpec;
use crate::workload::models::{ModelId, ModelSpec};
use std::collections::BTreeMap;

/// Handle for a batch admitted by [`PlatformSim::begin`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchHandle(u64);

#[derive(Clone, Debug)]
struct ActiveBatch {
    model: ModelId,
    mem_ticket: u64,
    compute_demand: f64,
}

/// Simulated edge platform with explicit resource occupancy.
#[derive(Clone, Debug)]
pub struct PlatformSim {
    pub spec: PlatformSpec,
    pub latency: LatencyModel,
    pub interference: InterferenceModel,
    memory: MemoryPool,
    active: BTreeMap<u64, ActiveBatch>,
    next_handle: u64,
}

impl PlatformSim {
    pub fn new(spec: PlatformSpec) -> Self {
        let latency =
            LatencyModel::calibrated().with_compute_scale(spec.compute_scale);
        PlatformSim {
            memory: MemoryPool::new(spec.memory_mb),
            latency,
            interference: InterferenceModel::default(),
            spec,
            active: BTreeMap::new(),
            next_handle: 0,
        }
    }

    /// Xavier NX with calibrated defaults — the paper's primary setup.
    pub fn xavier_nx() -> Self {
        Self::new(PlatformSpec::xavier_nx())
    }

    /// Current aggregate load (what executing batches experience, and the
    /// exact features §IV-F's predictor is given).
    pub fn current_load(&self) -> SystemLoad {
        SystemLoad {
            active_instances: self.active.len(),
            compute_demand: self
                .active
                .values()
                .map(|a| a.compute_demand)
                .sum(),
            memory_pressure: self.memory.pressure(),
        }
    }

    /// Memory utilization in [0, 1].
    pub fn memory_pressure(&self) -> f64 {
        self.memory.pressure()
    }

    pub fn free_memory_mb(&self) -> f64 {
        self.memory.free_mb()
    }

    pub fn active_batches(&self) -> usize {
        self.active.len()
    }

    /// Admit one instance-batch: reserve memory + register demand.
    pub fn begin(&mut self, model: ModelId, batch: usize)
                 -> Result<BatchHandle, OomError> {
        let spec = ModelSpec::get(model);
        let mb = spec.memory.total_mb(batch, 1);
        let mem_ticket = self.memory.reserve(mb)?;
        let handle = BatchHandle(self.next_handle);
        self.next_handle += 1;
        self.active.insert(
            handle.0,
            ActiveBatch {
                model,
                mem_ticket,
                compute_demand: spec.compute_demand,
            },
        );
        Ok(handle)
    }

    /// Price a batch of `model` under the *current* occupancy. Call after
    /// `begin`-ing everything that runs concurrently.
    pub fn duration_ms(&self, model: ModelId, batch: usize) -> f64 {
        let load = self.current_load();
        let inflate = self.interference.inflation(&load, &self.spec);
        self.latency.isolated_ms(model, batch) * inflate
    }

    /// Ground-truth inflation factor under current load (the interference
    /// predictor's regression target).
    pub fn current_inflation(&self) -> f64 {
        self.interference
            .inflation(&self.current_load(), &self.spec)
    }

    /// Finish a batch: release memory + demand. Unknown handles are a
    /// programming error.
    pub fn end(&mut self, handle: BatchHandle) {
        let b = self
            .active
            .remove(&handle.0)
            .expect("end() on unknown batch handle");
        self.memory.release(b.mem_ticket);
        let _ = b.model;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn begin_end_cycle_restores_resources() {
        let mut sim = PlatformSim::xavier_nx();
        let free0 = sim.free_memory_mb();
        let h = sim.begin(ModelId::Res, 8).unwrap();
        assert!(sim.free_memory_mb() < free0);
        assert_eq!(sim.active_batches(), 1);
        sim.end(h);
        assert_eq!(sim.free_memory_mb(), free0);
        assert_eq!(sim.active_batches(), 0);
    }

    #[test]
    fn concurrency_inflates_latency() {
        let mut sim = PlatformSim::xavier_nx();
        let solo = {
            let h = sim.begin(ModelId::Yolo, 8).unwrap();
            let d = sim.duration_ms(ModelId::Yolo, 8);
            sim.end(h);
            d
        };
        let mut handles = Vec::new();
        for _ in 0..6 {
            handles.push(sim.begin(ModelId::Yolo, 8).unwrap());
        }
        let crowded = sim.duration_ms(ModelId::Yolo, 8);
        for h in handles {
            sim.end(h);
        }
        assert!(crowded > 1.2 * solo, "solo {solo} crowded {crowded}");
    }

    #[test]
    fn fig1_oom_corner_rejected() {
        let mut sim = PlatformSim::xavier_nx();
        // batch 128 × several yolo instances must eventually OOM.
        let mut oom = false;
        let mut handles = Vec::new();
        for _ in 0..8 {
            match sim.begin(ModelId::Yolo, 128) {
                Ok(h) => handles.push(h),
                Err(_) => {
                    oom = true;
                    break;
                }
            }
        }
        assert!(oom, "expected OOM at the Fig. 1 corner");
    }

    #[test]
    fn nano_slower_than_nx() {
        let nx = PlatformSim::xavier_nx();
        let nano = PlatformSim::new(PlatformSpec::jetson_nano());
        assert!(
            nano.duration_ms(ModelId::Res, 4) > 3.0 * nx.duration_ms(ModelId::Res, 4)
        );
    }

    #[test]
    #[should_panic(expected = "unknown batch handle")]
    fn double_end_panics() {
        let mut sim = PlatformSim::xavier_nx();
        let h = sim.begin(ModelId::Mob, 1).unwrap();
        sim.end(h);
        sim.end(h);
    }
}
