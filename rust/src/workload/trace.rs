//! Workload trace record/replay: freeze a generated arrival sequence to
//! JSON so experiments are replayable bit-for-bit across schedulers (the
//! paper compares schedulers under the *same* arrival process).

use super::models::ModelId;
use super::request::Request;
use crate::util::json::{self, Json};

/// A recorded arrival sequence.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    pub requests: Vec<Request>,
}

impl Trace {
    pub fn from_requests(requests: Vec<Request>) -> Self {
        Trace { requests }
    }

    /// Serialize to JSON text.
    pub fn to_json(&self) -> String {
        let items = self.requests.iter().map(|r| {
            json::obj(vec![
                ("id", json::num(r.id as f64)),
                ("model", json::s(r.model.name())),
                ("arrival_ms", json::num(r.arrival_ms)),
                ("slo_ms", json::num(r.slo_ms)),
                ("tx_ms", json::num(r.transmission_ms)),
            ])
        });
        json::obj(vec![
            ("format", json::s("bcedge-trace-v1")),
            ("requests", json::arr(items)),
        ])
        .to_string()
    }

    /// Parse from JSON text.
    pub fn from_json(text: &str) -> Result<Trace, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        if v.get("format").and_then(Json::as_str) != Some("bcedge-trace-v1") {
            return Err("not a bcedge trace".into());
        }
        let items = v
            .get("requests")
            .and_then(Json::as_arr)
            .ok_or("missing requests")?;
        let mut requests = Vec::with_capacity(items.len());
        for it in items {
            let model_name =
                it.get("model").and_then(Json::as_str).ok_or("model")?;
            let model =
                ModelId::from_name(model_name).ok_or("unknown model")?;
            let mut r = Request::new(
                it.get("id").and_then(Json::as_f64).ok_or("id")? as u64,
                model,
                it.get("arrival_ms").and_then(Json::as_f64).ok_or("arrival")?,
            );
            r.slo_ms = it.get("slo_ms").and_then(Json::as_f64).ok_or("slo")?;
            r.transmission_ms =
                it.get("tx_ms").and_then(Json::as_f64).unwrap_or(0.0);
            requests.push(r);
        }
        Ok(Trace { requests })
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    pub fn load(path: &str) -> Result<Trace, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::envelope::{RateEnvelope, ShapedGenerator};
    use crate::workload::generator::PoissonGenerator;

    #[test]
    fn json_round_trip() {
        let mut g = PoissonGenerator::new(40.0, 3);
        let trace = Trace::from_requests(g.generate_horizon(2_000.0));
        let text = trace.to_json();
        let back = Trace::from_json(&text).unwrap();
        assert_eq!(trace, back);
    }

    /// Record → JSON → replay must be BIT-identical, field by field —
    /// including per-request SLO overrides and transmission times with
    /// awkward f64 values (the writer prints shortest-round-trip
    /// decimals, so exact f64 equality is the contract, not tolerance).
    #[test]
    fn round_trip_is_bit_identical_with_custom_slo_and_tx() {
        let mut requests = Vec::new();
        for (i, (slo, tx)) in [
            (0.1 + 0.2, 1.0 / 3.0),          // classic non-representable
            (58.0, 0.0),                      // exact integers
            (1e-9, 2.5e3),                    // extreme magnitudes
            (f64::MAX / 1e10, f64::MIN_POSITIVE),
        ]
        .iter()
        .enumerate()
        {
            let mut r = Request::new(i as u64 * 7 + 1, ModelId::from_index(i),
                                     i as f64 * 1234.56789);
            r.slo_ms = *slo;
            r.transmission_ms = *tx;
            requests.push(r);
        }
        let trace = Trace::from_requests(requests);
        let back = Trace::from_json(&trace.to_json()).unwrap();
        assert_eq!(trace, back);
        for (a, b) in trace.requests.iter().zip(&back.requests) {
            assert!(a.slo_ms.to_bits() == b.slo_ms.to_bits(),
                    "slo bits diverged: {} vs {}", a.slo_ms, b.slo_ms);
            assert!(a.transmission_ms.to_bits() == b.transmission_ms.to_bits(),
                    "tx bits diverged");
            assert!(a.arrival_ms.to_bits() == b.arrival_ms.to_bits(),
                    "arrival bits diverged");
        }
    }

    /// A full generated trace (bursty envelope: fractional arrivals, SLOs
    /// from the zoo, random transmission) survives save → load through a
    /// real file bit-identically.
    #[test]
    fn file_round_trip_replays_generated_trace() {
        let mut g = ShapedGenerator::new(80.0, RateEnvelope::bursty(), 13);
        let trace = Trace::from_requests(g.generate_horizon(5_000.0));
        assert!(!trace.requests.is_empty());
        let path = std::env::temp_dir().join("bcedge_trace_roundtrip.json");
        let path = path.to_str().unwrap();
        trace.save(path).unwrap();
        let back = Trace::load(path).unwrap();
        std::fs::remove_file(path).ok();
        assert_eq!(trace, back);
        // Double round trip is a fixed point.
        assert_eq!(back.to_json(), Trace::from_json(&back.to_json())
            .unwrap()
            .to_json());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Trace::from_json("{}").is_err());
        assert!(Trace::from_json("not json").is_err());
        assert!(Trace::from_json(
            r#"{"format":"bcedge-trace-v1","requests":[{"model":"vgg"}]}"#
        )
        .is_err());
    }
}
