//! Request model and workload generation (paper §III-A1, §V-A), plus the
//! bursty/diurnal rate envelopes the serving load generator drives.

pub mod envelope;
pub mod generator;
pub mod models;
pub mod request;
pub mod session;
pub mod trace;

pub use envelope::{RateEnvelope, ShapedGenerator};
pub use generator::PoissonGenerator;
pub use models::{ModelId, ModelSpec, N_MODELS};
pub use request::Request;
pub use session::SessionSpec;
pub use trace::Trace;
