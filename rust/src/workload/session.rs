//! Autoregressive sessions: multi-round requests with dual SLOs.
//!
//! A one-shot request finishes when its batch drains. An LLM-style
//! request does not: one admission opens a *session* of `1 + N` rounds —
//! a prefill head followed by N decode steps — and each completed round
//! re-enters the queue as the next one. The two halves carry different
//! deadlines, following the TTFT/TPOT split used by SLO-driven LLM
//! serving on edge devices (SLICE, arxiv 2510.18544; EdgeServing,
//! arxiv 2605.05527):
//!
//! - **TTFT** (time-to-first-token): the head's completion deadline —
//!   the model's e2e SLO scaled by [`SessionSpec::ttft_slo_scale`].
//! - **TPOT** (time-per-output-token): every decode step's cadence
//!   budget, a flat [`SessionSpec::tpot_ms`] from the *previous* step's
//!   completion.
//!
//! Sessions are driven from outside the engine: the serving tier
//! re-submits step `k + 1` when step `k` completes, so between steps a
//! session holds no engine resources at all — any tighter-slack request
//! (one-shot or another session's step) may jump ahead, and nothing can
//! preempt a step mid-batch. That contract is what makes sessions
//! composable with EDF batching, migration, drain, and the result cache
//! seams without new locking.
//!
//! ## Step identity
//!
//! Every round is an ordinary [`crate::workload::Request`] with an id
//! derived from the head's: the step index lives in the top byte,
//! `step_id = head_id | (k << 56)`. Node-scoped id windows use at most
//! 47 bits (node stride `2^40` + incarnation stride `2^32` + sequence),
//! and trace ids are dense small integers, so the top byte is free in
//! every driver. This keeps step ids unique cluster-wide (head ids
//! already are), makes the step index recoverable from any completion
//! event without a side table, and leaves the low bits intact so the
//! node that served the head is recoverable from any step's id.

use crate::workload::models::{ModelId, ModelSpec};
use crate::workload::request::Request;

/// Bit position of the step index inside a step id.
pub const STEP_SHIFT: u32 = 56;

/// Mask selecting the head id (everything below the step byte).
pub const HEAD_MASK: u64 = (1u64 << STEP_SHIFT) - 1;

/// Maximum decode steps a session may be configured with (the step
/// index must fit the top byte).
pub const MAX_DECODE_STEPS: u32 = 255;

/// Step index of a request id: 0 for heads (and for every one-shot
/// request), `k ≥ 1` for the k-th decode step.
pub fn step_of(id: u64) -> u64 {
    id >> STEP_SHIFT
}

/// The head id a step id was derived from (identity on heads).
pub fn head_of(id: u64) -> u64 {
    id & HEAD_MASK
}

/// Id of decode step `k` (1-based) of the session whose head is `id`.
pub fn step_id(head_id: u64, k: u64) -> u64 {
    debug_assert_eq!(step_of(head_id), 0, "head id has a step byte set");
    debug_assert!(k >= 1 && k <= MAX_DECODE_STEPS as u64);
    head_id | (k << STEP_SHIFT)
}

/// Shape of every session in an LLM-style workload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SessionSpec {
    /// Decode steps after the head (so a session is `1 + decode_steps`
    /// rounds total). At least 1 — a zero-step "session" is a one-shot.
    pub decode_steps: u32,
    /// TTFT deadline as a multiple of the model's e2e SLO. Applied to
    /// the head after stamping (no RNG draw), so the non-LLM arrival
    /// stream is untouched bit-for-bit.
    pub ttft_slo_scale: f64,
    /// TPOT budget, ms: each decode step's SLO, measured from the
    /// previous round's completion.
    pub tpot_ms: f64,
}

impl SessionSpec {
    pub fn new(decode_steps: u32, ttft_slo_scale: f64, tpot_ms: f64) -> Self {
        assert!(
            (1..=MAX_DECODE_STEPS).contains(&decode_steps),
            "decode steps must be in 1..={MAX_DECODE_STEPS}, got {decode_steps}"
        );
        assert!(ttft_slo_scale > 0.0, "ttft slo scale must be positive");
        assert!(tpot_ms > 0.0, "tpot budget must be positive");
        SessionSpec { decode_steps, ttft_slo_scale, tpot_ms }
    }

    /// Total rounds per session, head included.
    pub fn rounds(&self) -> u64 {
        1 + self.decode_steps as u64
    }

    /// Re-stamp a freshly generated request as a session head: its SLO
    /// becomes the TTFT deadline. Pure arithmetic — the generator's RNG
    /// call order is a reproducibility contract and must not change.
    pub fn stamp_head(&self, r: &mut Request) {
        r.slo_ms *= self.ttft_slo_scale;
    }

    /// Build decode step `k + 1` from round `k`'s completion (taken
    /// straight off a completion stream: the finished round's id, model,
    /// and completion time). The step arrives the instant its
    /// predecessor finished, carries the flat TPOT budget as its SLO,
    /// and is charged `transmission_ms` (the token payload's
    /// contention-inflated link time; 0 on infinite-bandwidth links).
    /// `None` once the session is over. No RNG is consumed.
    pub fn next_step(
        &self,
        prev_id: u64,
        model: ModelId,
        completed_ms: f64,
        transmission_ms: f64,
    ) -> Option<Request> {
        let k = step_of(prev_id) + 1;
        if k > self.decode_steps as u64 {
            return None;
        }
        Some(Request {
            id: step_id(head_of(prev_id), k),
            model,
            arrival_ms: completed_ms,
            slo_ms: self.tpot_ms,
            transmission_ms,
        })
    }

    /// Whole-session cadence feasibility at admission: a session is
    /// only worth opening if the serving estimate for one round fits
    /// the TPOT budget — otherwise every decode step is born late and
    /// the session would burn `decode_steps` slots to miss every
    /// deadline. Heads of infeasible sessions are shed as
    /// [`crate::metrics::ShedReason::SessionAbort`].
    pub fn cadence_feasible(&self, service_est_ms: f64) -> bool {
        service_est_ms <= self.tpot_ms
    }

    /// A conservative per-step service floor for feasibility checks
    /// when no live gauge is available: the model's profiled batch-1
    /// latency.
    pub fn service_floor_ms(spec: &ModelSpec) -> f64 {
        // compute_demand is the profiled batch-1 latency in ms on the
        // reference platform; real gauges refine this, the floor only
        // rejects sessions that cannot possibly hold cadence.
        spec.compute_demand
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::ModelId;

    fn head(id: u64) -> Request {
        Request {
            id,
            model: ModelId::Yolo,
            arrival_ms: 10.0,
            slo_ms: 138.0,
            transmission_ms: 1.0,
        }
    }

    #[test]
    fn step_ids_round_trip_and_stay_unique() {
        // Worst-case head id: max node window bits all set.
        let head_id = (1u64 << 47) - 1;
        let mut seen = std::collections::HashSet::new();
        seen.insert(head_id);
        for k in 1..=MAX_DECODE_STEPS as u64 {
            let sid = step_id(head_id, k);
            assert_eq!(step_of(sid), k);
            assert_eq!(head_of(sid), head_id);
            assert!(seen.insert(sid), "collision at step {k}");
        }
    }

    #[test]
    fn next_step_chains_cadence_and_stops_at_n() {
        let spec = SessionSpec::new(2, 1.5, 40.0);
        let h = head(7);
        let s1 = spec
            .next_step(h.id, h.model, 55.0, 0.25)
            .expect("step 1");
        assert_eq!(step_of(s1.id), 1);
        assert_eq!(s1.arrival_ms, 55.0);
        assert_eq!(s1.slo_ms, 40.0);
        assert_eq!(s1.transmission_ms, 0.25);
        let s2 = spec
            .next_step(s1.id, s1.model, 90.0, 0.0)
            .expect("step 2");
        assert_eq!(step_of(s2.id), 2);
        assert_eq!(head_of(s2.id), 7);
        assert!(spec.next_step(s2.id, s2.model, 120.0, 0.0).is_none(),
                "session is over");
    }

    #[test]
    fn stamp_head_scales_ttft_only() {
        let spec = SessionSpec::new(4, 2.0, 40.0);
        let mut h = head(3);
        spec.stamp_head(&mut h);
        assert_eq!(h.slo_ms, 276.0);
        assert_eq!(h.arrival_ms, 10.0);
        assert_eq!(h.transmission_ms, 1.0);
    }

    #[test]
    fn cadence_feasibility_gates_on_tpot() {
        let spec = SessionSpec::new(4, 1.0, 40.0);
        assert!(spec.cadence_feasible(39.9));
        assert!(spec.cadence_feasible(40.0));
        assert!(!spec.cadence_feasible(40.1));
    }

    #[test]
    #[should_panic(expected = "decode steps")]
    fn zero_step_sessions_are_rejected() {
        SessionSpec::new(0, 1.0, 40.0);
    }
}
