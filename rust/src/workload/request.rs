//! Inference request (paper §III-A1): rᵢ = {model type, input type,
//! input shape, SLOᵢ}.

use super::models::{ModelId, ModelSpec};

/// Unique request identifier.
pub type RequestId = u64;

/// One inference request as it flows through the coordinator.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: RequestId,
    pub model: ModelId,
    /// Arrival timestamp at the edge platform, ms.
    pub arrival_ms: f64,
    /// Service-level objective (deadline budget), ms. Defaults to the
    /// model's Table-IV SLO but is per-request, as in the paper.
    pub slo_ms: f64,
    /// Simulated network transmission time already spent reaching the
    /// platform (tᵢ_t of Eq. 2).
    pub transmission_ms: f64,
}

impl Request {
    /// Request with the model's default SLO and no transmission delay.
    pub fn new(id: RequestId, model: ModelId, arrival_ms: f64) -> Self {
        Request {
            id,
            model,
            arrival_ms,
            slo_ms: ModelSpec::get(model).slo_ms,
            transmission_ms: 0.0,
        }
    }

    /// Absolute deadline: arrival + SLO.
    pub fn deadline_ms(&self) -> f64 {
        self.arrival_ms + self.slo_ms
    }

    /// Remaining SLO budget at time `now_ms` (negative = already late).
    pub fn slack_ms(&self, now_ms: f64) -> f64 {
        self.deadline_ms() - now_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_from_table_iv() {
        let r = Request::new(1, ModelId::Res, 100.0);
        assert_eq!(r.slo_ms, 58.0);
        assert_eq!(r.deadline_ms(), 158.0);
        assert_eq!(r.slack_ms(150.0), 8.0);
        assert!(r.slack_ms(160.0) < 0.0);
    }
}
