//! Time-varying arrival-rate envelopes for the serving load generator.
//!
//! The paper evaluates under stationary Poisson traffic (§V-A), but a
//! serving runtime earns its keep under the loads real edges see: bursty
//! on/off traffic (a Markov-modulated Poisson process) and slow diurnal
//! swings. [`ShapedGenerator`] produces a non-homogeneous Poisson arrival
//! process by thinning a homogeneous process at the envelope's peak rate —
//! exact, and deterministic from the seed like every other generator in
//! the crate.

use super::generator::stamp_request;
use super::models::{ModelId, N_MODELS};
use super::request::Request;
use crate::util::rng::Pcg32;

/// Shape of the offered-rate curve over time, as a multiplier on the
/// generator's base rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RateEnvelope {
    /// Stationary Poisson at the base rate (the paper's §V-A model).
    Constant,
    /// MMPP on/off bursts: rate multiplier `hi` while bursting, `lo`
    /// otherwise, with exponentially distributed dwell times.
    Bursty {
        hi: f64,
        lo: f64,
        mean_on_ms: f64,
        mean_off_ms: f64,
    },
    /// Diurnal swing: multiplier `1 + depth · sin(2πt / period)`,
    /// time-compressed so a bench run sweeps a full "day".
    Diurnal { period_ms: f64, depth: f64 },
}

impl RateEnvelope {
    /// Default burst shape: 3× rate one quarter of the time.
    pub fn bursty() -> Self {
        RateEnvelope::Bursty {
            hi: 3.0,
            lo: 0.5,
            mean_on_ms: 2_000.0,
            mean_off_ms: 6_000.0,
        }
    }

    /// Default diurnal shape: ±60 % swing over a 60 s "day".
    pub fn diurnal() -> Self {
        RateEnvelope::Diurnal { period_ms: 60_000.0, depth: 0.6 }
    }

    /// Largest multiplier the envelope can reach (the thinning bound).
    pub fn peak(&self) -> f64 {
        match *self {
            RateEnvelope::Constant => 1.0,
            RateEnvelope::Bursty { hi, lo, .. } => hi.max(lo),
            RateEnvelope::Diurnal { depth, .. } => 1.0 + depth.abs(),
        }
    }

    /// Mean multiplier over time (for sizing sustained-load experiments).
    pub fn mean(&self) -> f64 {
        match *self {
            RateEnvelope::Constant => 1.0,
            RateEnvelope::Bursty { hi, lo, mean_on_ms, mean_off_ms } => {
                (hi * mean_on_ms + lo * mean_off_ms)
                    / (mean_on_ms + mean_off_ms)
            }
            RateEnvelope::Diurnal { .. } => 1.0,
        }
    }
}

/// Non-homogeneous Poisson request source: base rate × envelope, same
/// model-mix and transmission model as
/// [`super::generator::PoissonGenerator`].
#[derive(Clone, Debug)]
pub struct ShapedGenerator {
    /// Base aggregate arrival rate, requests/second.
    pub rps: f64,
    pub envelope: RateEnvelope,
    /// Per-model mixing weights (normalized internally).
    pub mix: [f64; N_MODELS],
    /// Multiplier on every request's Table-IV SLO (1.0 = the paper's
    /// deadlines). SLO-tightness is its own experiment axis (Fig. 15);
    /// heterogeneous-cluster runs loosen it so slower platforms are
    /// feasible for part of the zoo instead of none of it.
    pub slo_scale: f64,
    next_id: u64,
    now_ms: f64,
    rng: Pcg32,
    /// MMPP phase state: currently in the `hi` (burst) phase, and when
    /// the current phase ends.
    burst_on: bool,
    phase_until_ms: f64,
}

impl ShapedGenerator {
    pub fn new(rps: f64, envelope: RateEnvelope, seed: u64) -> Self {
        assert!(rps > 0.0);
        ShapedGenerator {
            rps,
            envelope,
            mix: [1.0; N_MODELS],
            slo_scale: 1.0,
            next_id: 0,
            now_ms: 0.0,
            rng: Pcg32::seeded(seed),
            burst_on: false,
            phase_until_ms: 0.0,
        }
    }

    /// Restrict to a subset of models.
    pub fn with_models(mut self, models: &[ModelId]) -> Self {
        self.mix = [0.0; N_MODELS];
        for &m in models {
            self.mix[m as usize] = 1.0;
        }
        self
    }

    /// Scale every generated request's SLO by `scale` (> 0). Does not
    /// perturb the RNG stream: a scaled run sees the same arrivals,
    /// models, and transmission stamps as an unscaled one.
    pub fn with_slo_scale(mut self, scale: f64) -> Self {
        assert!(scale > 0.0);
        self.slo_scale = scale;
        self
    }

    /// Envelope multiplier at `t_ms`, advancing MMPP phases as needed.
    fn multiplier_at(&mut self, t_ms: f64) -> f64 {
        match self.envelope {
            RateEnvelope::Constant => 1.0,
            RateEnvelope::Diurnal { period_ms, depth } => {
                1.0 + depth
                    * (2.0 * std::f64::consts::PI * t_ms / period_ms).sin()
            }
            RateEnvelope::Bursty { hi, lo, mean_on_ms, mean_off_ms } => {
                while t_ms >= self.phase_until_ms {
                    self.burst_on = !self.burst_on;
                    let mean = if self.burst_on { mean_on_ms } else { mean_off_ms };
                    self.phase_until_ms +=
                        self.rng.exponential(1.0 / mean.max(1e-9));
                }
                if self.burst_on {
                    hi
                } else {
                    lo
                }
            }
        }
    }

    /// Next request via thinning: candidate arrivals at the peak rate,
    /// each accepted with probability λ(t)/λ_peak.
    pub fn next_request(&mut self) -> Request {
        let peak_rps = self.rps * self.envelope.peak();
        loop {
            let dt_ms = self.rng.exponential(peak_rps) * 1e3;
            self.now_ms += dt_ms;
            let m = self.multiplier_at(self.now_ms);
            let accept = m / self.envelope.peak();
            if self.rng.f64() >= accept {
                continue;
            }
            // Same model-mix + transmission stamping as PoissonGenerator
            // (shared helper, so the request model cannot drift).
            let mut r = stamp_request(&mut self.rng, &self.mix,
                                      &mut self.next_id, self.now_ms);
            r.slo_ms *= self.slo_scale;
            return r;
        }
    }

    /// All requests arriving within [0, horizon_ms).
    pub fn generate_horizon(&mut self, horizon_ms: f64) -> Vec<Request> {
        let mut out = Vec::new();
        loop {
            let r = self.next_request();
            if r.arrival_ms >= horizon_ms {
                break;
            }
            out.push(r);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rate_in_window(reqs: &[Request], lo_ms: f64, hi_ms: f64) -> f64 {
        let n = reqs
            .iter()
            .filter(|r| r.arrival_ms >= lo_ms && r.arrival_ms < hi_ms)
            .count();
        n as f64 / ((hi_ms - lo_ms) / 1e3)
    }

    #[test]
    fn constant_envelope_matches_base_rate() {
        let mut g = ShapedGenerator::new(40.0, RateEnvelope::Constant, 3);
        let reqs = g.generate_horizon(120_000.0);
        let rate = reqs.len() as f64 / 120.0;
        assert!((rate - 40.0).abs() < 2.5, "rate {rate}");
        assert!(reqs.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        let mut ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        ids.dedup();
        assert_eq!(ids.len(), reqs.len());
    }

    #[test]
    fn bursty_envelope_hits_mean_rate_with_extra_variance() {
        let env = RateEnvelope::bursty();
        let mut g = ShapedGenerator::new(40.0, env, 5);
        let horizon_s = 240.0;
        let reqs = g.generate_horizon(horizon_s * 1e3);
        let rate = reqs.len() as f64 / horizon_s;
        let expect = 40.0 * env.mean();
        assert!((rate - expect).abs() < 0.25 * expect,
                "rate {rate} vs expected {expect}");
        // Burstiness: per-second counts must be overdispersed vs Poisson
        // (index of dispersion var/mean ≫ 1; ≈1 for constant-rate).
        let mut counts = vec![0f64; horizon_s as usize];
        for r in &reqs {
            counts[(r.arrival_ms / 1e3) as usize] += 1.0;
        }
        let mean = counts.iter().sum::<f64>() / counts.len() as f64;
        let var = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>()
            / counts.len() as f64;
        assert!(var / mean > 2.0, "dispersion {} not bursty", var / mean);
    }

    #[test]
    fn diurnal_envelope_peaks_and_troughs() {
        // period 40 s, depth 0.8: quarter-period windows around the peak
        // (t=10 s) and trough (t=30 s) must differ strongly.
        let env = RateEnvelope::Diurnal { period_ms: 40_000.0, depth: 0.8 };
        let mut g = ShapedGenerator::new(60.0, env, 7);
        let reqs = g.generate_horizon(40_000.0);
        let peak = rate_in_window(&reqs, 5_000.0, 15_000.0);
        let trough = rate_in_window(&reqs, 25_000.0, 35_000.0);
        assert!(peak > 2.0 * trough, "peak {peak} vs trough {trough}");
    }

    #[test]
    fn deterministic_from_seed_and_model_restriction() {
        let env = RateEnvelope::bursty();
        let a = ShapedGenerator::new(50.0, env, 11)
            .with_models(&[ModelId::Yolo])
            .generate_horizon(20_000.0);
        let b = ShapedGenerator::new(50.0, env, 11)
            .with_models(&[ModelId::Yolo])
            .generate_horizon(20_000.0);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert!(a.iter().all(|r| r.model == ModelId::Yolo));
    }

    /// Loadgen determinism pin: for EVERY envelope, the same seed yields
    /// an identical arrival stream — ids, arrival times, model picks,
    /// SLOs, and transmission stamps all bit-equal across two fresh
    /// generators. Guards the shared `stamp_request` helper (and the
    /// envelope-specific RNG call order) against drift: bench-serve
    /// comparisons across configs are only fair if `--seed` pins the
    /// offered load exactly.
    #[test]
    fn same_seed_identical_stream_for_every_envelope() {
        for envelope in [RateEnvelope::Constant, RateEnvelope::bursty(),
                         RateEnvelope::diurnal()] {
            let gen = |seed: u64| {
                ShapedGenerator::new(75.0, envelope, seed)
                    .generate_horizon(30_000.0)
            };
            let a = gen(42);
            let b = gen(42);
            assert!(!a.is_empty(), "{envelope:?} produced nothing");
            assert_eq!(a.len(), b.len(), "{envelope:?} stream lengths");
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id, "{envelope:?} ids diverged");
                assert!(x.arrival_ms.to_bits() == y.arrival_ms.to_bits()
                            && x.transmission_ms.to_bits()
                                == y.transmission_ms.to_bits()
                            && x.slo_ms.to_bits() == y.slo_ms.to_bits(),
                        "{envelope:?} stamps diverged at id {}", x.id);
                assert_eq!(x.model, y.model);
            }
            // A different seed must diverge (the stream is genuinely
            // seed-driven, not constant).
            assert_ne!(a, gen(43), "{envelope:?} ignores its seed");
        }
    }

    /// SLO scaling stretches deadlines without touching the arrival
    /// stream: same ids, times, models, and transmission stamps.
    #[test]
    fn slo_scale_stretches_deadlines_only() {
        let base = ShapedGenerator::new(50.0, RateEnvelope::Constant, 13)
            .generate_horizon(10_000.0);
        let scaled = ShapedGenerator::new(50.0, RateEnvelope::Constant, 13)
            .with_slo_scale(3.0)
            .generate_horizon(10_000.0);
        assert_eq!(base.len(), scaled.len());
        for (a, b) in base.iter().zip(&scaled) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.model, b.model);
            assert_eq!(a.arrival_ms.to_bits(), b.arrival_ms.to_bits());
            assert_eq!(a.transmission_ms.to_bits(),
                       b.transmission_ms.to_bits());
            assert!((b.slo_ms - 3.0 * a.slo_ms).abs() < 1e-9);
        }
    }

    #[test]
    fn peak_and_mean_multipliers() {
        assert_eq!(RateEnvelope::Constant.peak(), 1.0);
        assert_eq!(RateEnvelope::bursty().peak(), 3.0);
        let d = RateEnvelope::diurnal();
        assert!((d.peak() - 1.6).abs() < 1e-12);
        assert_eq!(d.mean(), 1.0);
        let b = RateEnvelope::Bursty {
            hi: 4.0,
            lo: 0.0,
            mean_on_ms: 1_000.0,
            mean_off_ms: 3_000.0,
        };
        assert!((b.mean() - 1.0).abs() < 1e-12);
    }
}
