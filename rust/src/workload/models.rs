//! The served model zoo — paper Table IV, mirrored by the AOT manifest.
//!
//! `ModelId` is the coordinator's compact handle; `ModelSpec` carries the
//! static properties the scheduler and platform model need (SLO, shapes,
//! memory demand). Values must agree with `python/compile/model.py`
//! (enforced by `runtime::artifacts` when loading the manifest).

use crate::platform::memory::MemoryDemand;

/// Number of models in the zoo.
pub const N_MODELS: usize = 6;

/// Compact model handle (indexes every per-model table in the crate).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(usize)]
pub enum ModelId {
    Yolo = 0,
    Mob = 1,
    Res = 2,
    Eff = 3,
    Inc = 4,
    Bert = 5,
}

impl ModelId {
    pub fn all() -> [ModelId; N_MODELS] {
        use ModelId::*;
        [Yolo, Mob, Res, Eff, Inc, Bert]
    }

    pub fn from_index(i: usize) -> ModelId {
        Self::all()[i]
    }

    pub fn from_name(name: &str) -> Option<ModelId> {
        ModelId::all()
            .into_iter()
            .find(|m| ModelSpec::get(*m).name == name)
    }

    pub fn name(&self) -> &'static str {
        ModelSpec::get(*self).name
    }
}

/// Static per-model description (paper Table IV + memory demands).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub id: ModelId,
    /// Short zoo name used in the manifest ("yolo", "mob", …).
    pub name: &'static str,
    /// Paper name (Table IV).
    pub paper_name: &'static str,
    /// Service-level objective, ms (Table IV).
    pub slo_ms: f64,
    /// Per-sample input element count (f32), excluding batch dim.
    pub input_elems: usize,
    /// Per-sample output element count.
    pub output_elems: usize,
    /// Memory demand for the platform model. Weights follow the paper's
    /// TensorRT engine sizes (hundreds of MB); activations scale with the
    /// paper's 224×224 inputs so the Fig. 1 OOM corner reproduces.
    pub memory: MemoryDemand,
    /// Normalized compute demand of one running instance (drives the
    /// interference model's load term; 1.0 ≈ YOLO).
    pub compute_demand: f64,
}

const SPECS: [ModelSpec; N_MODELS] = [
    ModelSpec {
        id: ModelId::Yolo,
        name: "yolo",
        paper_name: "YOLO-v5",
        slo_ms: 138.0,
        input_elems: 3 * 32 * 32,
        output_elems: 192 * 15,
        memory: MemoryDemand { weights_mb: 420.0, activation_mb_per_sample: 14.0 },
        compute_demand: 1.0,
    },
    ModelSpec {
        id: ModelId::Mob,
        name: "mob",
        paper_name: "MobileNet-v3",
        slo_ms: 86.0,
        input_elems: 3 * 32 * 32,
        output_elems: 10,
        memory: MemoryDemand { weights_mb: 110.0, activation_mb_per_sample: 5.0 },
        compute_demand: 0.30,
    },
    ModelSpec {
        id: ModelId::Res,
        name: "res",
        paper_name: "ResNet-18",
        slo_ms: 58.0,
        input_elems: 3 * 32 * 32,
        output_elems: 10,
        memory: MemoryDemand { weights_mb: 180.0, activation_mb_per_sample: 7.0 },
        compute_demand: 0.45,
    },
    ModelSpec {
        id: ModelId::Eff,
        name: "eff",
        paper_name: "EfficientNet-B0",
        slo_ms: 93.0,
        input_elems: 3 * 32 * 32,
        output_elems: 10,
        memory: MemoryDemand { weights_mb: 150.0, activation_mb_per_sample: 8.0 },
        compute_demand: 0.40,
    },
    ModelSpec {
        id: ModelId::Inc,
        name: "inc",
        paper_name: "Inception-v3",
        slo_ms: 66.0,
        input_elems: 3 * 32 * 32,
        output_elems: 10,
        memory: MemoryDemand { weights_mb: 260.0, activation_mb_per_sample: 9.0 },
        compute_demand: 0.50,
    },
    ModelSpec {
        id: ModelId::Bert,
        name: "bert",
        paper_name: "TinyBERT",
        slo_ms: 114.0,
        input_elems: 14,
        output_elems: 12,
        memory: MemoryDemand { weights_mb: 200.0, activation_mb_per_sample: 4.0 },
        compute_demand: 0.60,
    },
];

impl ModelSpec {
    pub fn get(id: ModelId) -> &'static ModelSpec {
        &SPECS[id as usize]
    }

    pub fn all() -> &'static [ModelSpec; N_MODELS] {
        &SPECS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_iv_slos() {
        assert_eq!(ModelSpec::get(ModelId::Yolo).slo_ms, 138.0);
        assert_eq!(ModelSpec::get(ModelId::Mob).slo_ms, 86.0);
        assert_eq!(ModelSpec::get(ModelId::Res).slo_ms, 58.0);
        assert_eq!(ModelSpec::get(ModelId::Eff).slo_ms, 93.0);
        assert_eq!(ModelSpec::get(ModelId::Inc).slo_ms, 66.0);
        assert_eq!(ModelSpec::get(ModelId::Bert).slo_ms, 114.0);
    }

    #[test]
    fn name_round_trip() {
        for m in ModelId::all() {
            assert_eq!(ModelId::from_name(m.name()), Some(m));
            assert_eq!(ModelId::from_index(m as usize), m);
        }
        assert_eq!(ModelId::from_name("vgg"), None);
    }

    #[test]
    fn fig1_oom_corner_exists() {
        // Paper Fig. 1: batch 128 × 8 heavy instances must exceed Xavier
        // NX memory — the scheduler has to learn to avoid that corner.
        use crate::platform::spec::PlatformSpec;
        let demand = ModelSpec::get(ModelId::Yolo).memory.total_mb(128, 8);
        assert!(demand > PlatformSpec::xavier_nx().memory_mb,
                "OOM corner missing: {demand} MB");
        // …while a moderate configuration fits comfortably.
        let ok = ModelSpec::get(ModelId::Yolo).memory.total_mb(8, 2);
        assert!(ok < 0.5 * PlatformSpec::xavier_nx().memory_mb);
    }
}
