//! Open-loop Poisson arrival generator — the paper's request model:
//! "requests arrive at BCEdge online at random with a Poisson
//! distribution", default 30 rps (§V-A).

use super::models::{ModelId, ModelSpec, N_MODELS};
use super::request::Request;
use crate::util::rng::Pcg32;

/// Poisson request source over the model zoo.
#[derive(Clone, Debug)]
pub struct PoissonGenerator {
    /// Aggregate arrival rate, requests/second.
    pub rps: f64,
    /// Per-model mixing weights (normalized internally).
    pub mix: [f64; N_MODELS],
    next_id: u64,
    now_ms: f64,
    rng: Pcg32,
}

impl PoissonGenerator {
    /// Uniform mix over the whole zoo at `rps` requests/second.
    pub fn new(rps: f64, seed: u64) -> Self {
        PoissonGenerator {
            rps,
            mix: [1.0; N_MODELS],
            next_id: 0,
            now_ms: 0.0,
            rng: Pcg32::seeded(seed),
        }
    }

    /// Restrict to a subset of models (Fig. 11 uses {yolo, res, bert}).
    pub fn with_models(mut self, models: &[ModelId]) -> Self {
        self.mix = [0.0; N_MODELS];
        for &m in models {
            self.mix[m as usize] = 1.0;
        }
        self
    }

    /// Weighted mix.
    pub fn with_mix(mut self, mix: [f64; N_MODELS]) -> Self {
        assert!(mix.iter().any(|&w| w > 0.0));
        self.mix = mix;
        self
    }

    /// Next request (exponential inter-arrival, categorical model pick).
    pub fn next_request(&mut self) -> Request {
        let dt_ms = self.rng.exponential(self.rps) * 1e3;
        self.now_ms += dt_ms;
        stamp_request(&mut self.rng, &self.mix, &mut self.next_id, self.now_ms)
    }

    /// All requests arriving within [0, horizon_ms).
    pub fn generate_horizon(&mut self, horizon_ms: f64) -> Vec<Request> {
        let mut out = Vec::new();
        loop {
            let r = self.next_request();
            if r.arrival_ms >= horizon_ms {
                break;
            }
            out.push(r);
        }
        out
    }
}

/// Stamp one request arriving at `now_ms`: categorical model pick over
/// `mix`, sequential id, and the simulated IoT→edge transmission time
/// (Eq. 2 tᵢ_t): ~1–3 ms for an image frame on local Wi-Fi/Ethernet,
/// scaled by input size. Shared by every arrival generator (Poisson and
/// the envelope-shaped serving load) so the request model cannot drift
/// between them. RNG call order — categorical, then one `f64` — is part
/// of the contract: trace seeds reproduce bit-for-bit across releases.
pub(crate) fn stamp_request(rng: &mut Pcg32, mix: &[f64; N_MODELS],
                            next_id: &mut u64, now_ms: f64) -> Request {
    let model = ModelId::from_index(rng.categorical(mix));
    let id = *next_id;
    *next_id += 1;
    let mut r = Request::new(id, model, now_ms);
    let elems = ModelSpec::get(model).input_elems as f64;
    r.transmission_ms = 0.5 + 2.5 * (elems / 3072.0).min(1.0) * rng.f64();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_rate_matches_rps() {
        let mut g = PoissonGenerator::new(30.0, 7);
        let reqs = g.generate_horizon(60_000.0); // 60 s
        let rate = reqs.len() as f64 / 60.0;
        assert!((rate - 30.0).abs() < 2.0, "rate {rate}");
    }

    #[test]
    fn arrivals_are_monotone_with_unique_ids() {
        let mut g = PoissonGenerator::new(50.0, 8);
        let reqs = g.generate_horizon(10_000.0);
        assert!(reqs.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        let mut ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        ids.dedup();
        assert_eq!(ids.len(), reqs.len());
    }

    #[test]
    fn model_restriction_respected() {
        let mut g = PoissonGenerator::new(100.0, 9)
            .with_models(&[ModelId::Yolo, ModelId::Bert]);
        let reqs = g.generate_horizon(5_000.0);
        assert!(!reqs.is_empty());
        assert!(reqs
            .iter()
            .all(|r| r.model == ModelId::Yolo || r.model == ModelId::Bert));
        assert!(reqs.iter().any(|r| r.model == ModelId::Yolo));
        assert!(reqs.iter().any(|r| r.model == ModelId::Bert));
    }

    #[test]
    fn interarrival_is_exponential_ish() {
        // CV (std/mean) of exponential inter-arrivals ≈ 1.
        let mut g = PoissonGenerator::new(100.0, 10);
        let reqs = g.generate_horizon(100_000.0);
        let gaps: Vec<f64> = reqs.windows(2).map(|w| w[1].arrival_ms - w[0].arrival_ms).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.1, "cv {cv}");
    }
}
