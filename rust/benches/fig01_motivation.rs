//! Paper Fig. 1 — motivational study: throughput (a) and end-to-end
//! latency (b) as functions of batch size × number of concurrent models,
//! YOLO-v5 on (simulated) NVIDIA Xavier NX.
//!
//! Expected shape (paper §I): both dimensions help at moderate values;
//! excessive batch/concurrency reduces throughput, inflates latency, and
//! eventually overflows memory.

use bcedge::platform::PlatformSim;
use bcedge::runtime::executor::{BatchJob, Dispatcher, SimDispatcher};
use bcedge::util::bench::{banner, Csv};
use bcedge::util::time::VirtualClock;
use bcedge::workload::models::ModelId;

const BATCHES: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];
const CONCS: [usize; 8] = [1, 2, 3, 4, 5, 6, 7, 8];

fn cell(model: ModelId, b: usize, c: usize) -> Option<(f64, f64)> {
    let mut d = SimDispatcher::new(PlatformSim::xavier_nx(), VirtualClock::new());
    let jobs: Vec<BatchJob> =
        (0..c).map(|_| BatchJob { model, batch: b, n_real: b }).collect();
    let res = d.run_group(&jobs);
    if res.iter().any(|r| r.is_err()) {
        return None;
    }
    let span = res.iter().map(|r| *r.as_ref().unwrap()).fold(0.0f64, f64::max);
    Some(((b * c) as f64 / (span / 1e3), span))
}

fn main() {
    let model = ModelId::Yolo;
    let mut csv = Csv::create("results/fig01_motivation.csv",
                              "batch,m_c,throughput_rps,latency_ms,oom")
        .expect("csv");

    for (title, pick) in [("Fig. 1(a) throughput (rps)", 0usize),
                          ("Fig. 1(b) latency (ms)", 1usize)] {
        banner(title);
        print!("{:>6}", "batch");
        for c in CONCS {
            print!(" {:>9}", format!("m_c={c}"));
        }
        println!();
        for b in BATCHES {
            print!("{b:>6}");
            for c in CONCS {
                match cell(model, b, c) {
                    Some((rps, lat)) => {
                        print!(" {:>9.1}", if pick == 0 { rps } else { lat });
                        if pick == 0 {
                            csv.rowf(&[b as f64, c as f64, rps, lat, 0.0]).ok();
                        }
                    }
                    None => {
                        print!(" {:>9}", "OOM");
                        if pick == 0 {
                            csv.rowf(&[b as f64, c as f64, f64::NAN,
                                       f64::NAN, 1.0]).ok();
                        }
                    }
                }
            }
            println!();
        }
    }

    // Shape assertions: interior throughput peak + OOM corner.
    let mut best = (0, 0, 0.0);
    for b in BATCHES {
        for c in CONCS {
            if let Some((rps, _)) = cell(model, b, c) {
                if rps > best.2 {
                    best = (b, c, rps);
                }
            }
        }
    }
    println!("\npeak: {:.1} rps at (batch={}, m_c={})", best.2, best.0, best.1);
    assert!(best.0 > 1 && best.1 > 1, "peak must need BOTH dimensions");
    assert!(best.0 < 128 && best.1 < 8, "peak must be interior");
    assert!(cell(model, 128, 8).is_none(), "extreme corner must OOM");
    println!("fig01 OK — wrote results/fig01_motivation.csv");
}
