//! Paper Fig. 16 — scheduling overhead: time per scheduling decision for
//! BCEdge (SAC), TAC, and DeepRT across the six models.
//!
//! Expected shape (§V-F): all decisions are sub-millisecond; BCEdge's
//! decision path is cheap relative to its utility gains (paper: 26 % /
//! 43 % lower average overhead than DeepRT / TAC — their numbers include
//! Triton round trips; ours isolate the decision function, so we assert
//! only the sub-ms property and report the relative ordering we measure).

use bcedge::coordinator::baselines::{self, DeepRtScheduler};
use bcedge::coordinator::sac_sched;
use bcedge::coordinator::{SchedCtx, Scheduler};
use bcedge::rl::ActionSpace;
use bcedge::util::bench::{banner, time_fn, Csv};
use bcedge::util::rng::Pcg32;
use bcedge::workload::models::{ModelId, ModelSpec};

fn ctx(model: ModelId) -> SchedCtx {
    SchedCtx {
        model,
        queue_len: 24,
        min_slack_ms: 40.0,
        slo_ms: ModelSpec::get(model).slo_ms,
        mem_free_frac: 0.6,
        compute_demand: 1.2,
        active_instances: 3,
        recent_latency_ms: 25.0,
        recent_throughput_rps: 80.0,
        recent_inflation: 1.3,
        cluster_backlog_ms: 0.0,
        cluster_share: 0.0,
        replica_share: 0.0,
    }
}

fn main() {
    banner("Fig. 16 — scheduling overhead (µs per decision)");
    let space = ActionSpace::standard();
    let mut rng = Pcg32::seeded(16);

    let mut sac = sac_sched::sac(space.clone(), &mut rng);
    let mut tac = baselines::tac(space.clone(), &mut rng);
    let mut deeprt = DeepRtScheduler::default();

    let mut csv = Csv::create("results/fig16_overhead.csv",
                              "model,bcedge_us,tac_us,deeprt_us").expect("csv");
    println!("{:<6} {:>12} {:>12} {:>12}", "model", "BCEdge", "TAC", "DeepRT");
    let mut means = [0.0f64; 3];
    for model in ModelId::all() {
        let c = ctx(model);
        let mut rows = [0.0f64; 3];
        let mut r1 = Pcg32::seeded(1);
        let t = time_fn("sac", 50, 400,
                        || { std::hint::black_box(sac.decide(&c, &mut r1)); });
        rows[0] = t.mean_us;
        let mut r2 = Pcg32::seeded(2);
        let t = time_fn("tac", 50, 400,
                        || { std::hint::black_box(tac.decide(&c, &mut r2)); });
        rows[1] = t.mean_us;
        let mut r3 = Pcg32::seeded(3);
        let t = time_fn("deeprt", 50, 400,
                        || { std::hint::black_box(deeprt.decide(&c, &mut r3)); });
        rows[2] = t.mean_us;
        println!("{:<6} {:>10.2}µs {:>10.2}µs {:>10.2}µs",
                 model.name(), rows[0], rows[1], rows[2]);
        csv.row(&[model.name().into(), format!("{:.3}", rows[0]),
                  format!("{:.3}", rows[1]), format!("{:.3}", rows[2])]).ok();
        for k in 0..3 {
            means[k] += rows[k] / 6.0;
        }
    }
    println!("\nmean: BCEdge {:.2}µs | TAC {:.2}µs | DeepRT {:.2}µs",
             means[0], means[1], means[2]);

    // Learning-path overhead (decide + feedback), the full per-slot cost.
    banner("per-slot decide+learn cost");
    let c = ctx(ModelId::Res);
    let mut r = Pcg32::seeded(4);
    let t = time_fn("sac decide+feedback", 20, 100, || {
        let a = sac.decide(&c, &mut r);
        std::hint::black_box(sac.feedback(&c, a, 1.0, &c, false, &mut r));
    });
    println!("{}", t.row());

    assert!(means[0] < 1000.0, "BCEdge decision must be sub-ms: {means:?}");
    println!("fig16 OK — wrote results/fig16_overhead.csv");
}
