//! Paper Fig. 15 — SLO-violation rate as offered load grows (10→40 rps),
//! BCEdge vs TAC vs DeepRT on the six-model zoo.
//!
//! Expected shape (§V-E): BCEdge lowest at every rate (paper: 53 % lower
//! than DeepRT, 25 % lower than TAC on average; ≤ 5 % even at 40 rps).

use bcedge::coordinator::harness::{Experiment, SchedKind};
use bcedge::util::bench::{banner, Csv};

fn main() {
    banner("Fig. 15 — SLO violation rate vs offered per-model rps");
    // Paper sweeps 10→40 rps on a testbed saturating near 40; our
    // calibrated platform saturates near 20 rps/model (120 aggregate), so
    // the sweep spans the same relative range of capacity.
    let rates = [5.0, 10.0, 15.0, 20.0];
    let kinds = [SchedKind::Sac, SchedKind::Tac, SchedKind::DeepRt];
    let mut csv = Csv::create("results/fig15_slo_vs_rps.csv",
                              "rps_per_model,bcedge,tac,deeprt").expect("csv");

    println!("{:>6} {:>10} {:>10} {:>10}", "rps/m", "BCEdge", "TAC", "DeepRT");
    let mut means = [0.0f64; 3];
    for &rps in &rates {
        let mut row = [0.0f64; 3];
        for (ki, kind) in kinds.iter().enumerate() {
            let mut e = Experiment::new(*kind);
            e.rps = rps;
            e.horizon_s = 300.0;
            let m = e.run();
            row[ki] = m.violation_rate();
            means[ki] += row[ki] / rates.len() as f64;
        }
        println!("{:>6.0} {:>9.2}% {:>9.2}% {:>9.2}%", rps,
                 row[0] * 100.0, row[1] * 100.0, row[2] * 100.0);
        csv.rowf(&[rps, row[0], row[1], row[2]]).ok();
    }
    println!("\nmean violation: BCEdge {:.2}% | TAC {:.2}% | DeepRT {:.2}%",
             means[0] * 100.0, means[1] * 100.0, means[2] * 100.0);
    println!("BCEdge vs DeepRT: −{:.0}% | vs TAC: −{:.0}%  (paper: −53%, −25%)",
             100.0 * (1.0 - means[0] / means[2].max(1e-9)),
             100.0 * (1.0 - means[0] / means[1].max(1e-9)));
    // Shape: BCEdge must clearly beat DeepRT; vs TAC we reproduce
    // parity-to-small-gains (see fig07 note + EXPERIMENTS.md).
    assert!(means[0] < means[2], "BCEdge must beat DeepRT: {means:?}");
    assert!(means[0] <= means[1] * 1.35,
            "BCEdge far behind TAC: {means:?}");
    println!("fig15 OK — wrote results/fig15_slo_vs_rps.csv");
}
