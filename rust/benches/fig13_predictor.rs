//! Paper Fig. 13 — CDF of the interference predictor's relative error:
//! the §IV-F two-layer NN vs the linear-regression baseline of [16]/[46].
//!
//! Protocol mirrors §V-E: 2000 profiled interference samples, 1600 train
//! / 400 validation. Expected shape: the NN's p90 error is roughly half
//! the linear model's (paper: 90 % of cases within 2.69 %, 95 % within
//! 3.25 %, "reduces the error rate by half compared to linear
//! regression").

use bcedge::platform::interference::{InterferenceModel, SystemLoad};
use bcedge::platform::PlatformSpec;
use bcedge::predictor::{InterferencePredictor, LinearPredictor, PredictorSample};
use bcedge::util::bench::{banner, Csv};
use bcedge::util::rng::Pcg32;
use bcedge::util::stats::ecdf;

fn profile_samples(n: usize, rng: &mut Pcg32) -> Vec<PredictorSample> {
    // Ground truth comes from the platform's interference surface exactly
    // as the profiler would record it during concurrent serving.
    let model = InterferenceModel::default();
    let nx = PlatformSpec::xavier_nx();
    (0..n)
        .map(|_| {
            let load = SystemLoad {
                active_instances: rng.range(1, 9),
                compute_demand: rng.f64() * 6.0,
                memory_pressure: rng.f64(),
            };
            PredictorSample {
                memory_pressure: load.memory_pressure,
                compute_demand: load.compute_demand,
                active_instances: load.active_instances,
                concurrency: load.active_instances.min(4),
                batch: 1 << rng.range(0, 8),
                inflation: model.inflation(&load, &nx),
            }
        })
        .collect()
}

fn rel_errors(pred: impl Fn(&PredictorSample) -> f64,
              test: &[PredictorSample]) -> Vec<f64> {
    test.iter()
        .map(|s| (pred(s) - s.inflation).abs() / s.inflation)
        .collect()
}

fn at(cdf: &[(f64, f64)], q: f64) -> f64 {
    cdf.iter().find(|(_, p)| *p >= q).map(|(x, _)| *x).unwrap_or(f64::NAN)
}

fn main() {
    banner("Fig. 13 — interference-prediction relative-error CDF (NN vs linreg)");
    let mut rng = Pcg32::seeded(1313);
    let all = profile_samples(2000, &mut rng); // paper: 2000 samples
    let (train, test) = all.split_at(1600);    // paper: 1600/400 split

    let mut nn = InterferencePredictor::new(&mut rng);
    for s in train {
        nn.observe(*s);
    }
    nn.fit(2500, &mut rng);

    let mut lr = LinearPredictor::new();
    lr.fit(train);

    let nn_err = rel_errors(|s| nn.predict(s), test);
    let lr_err = rel_errors(|s| lr.predict(s), test);
    let nn_cdf = ecdf(&nn_err);
    let lr_cdf = ecdf(&lr_err);

    let mut csv = Csv::create("results/fig13_predictor.csv",
                              "quantile,nn_rel_err,linreg_rel_err").expect("csv");
    println!("{:>9} {:>12} {:>12}", "quantile", "NN err", "linreg err");
    for q in [0.5, 0.75, 0.9, 0.95, 0.99] {
        let (n, l) = (at(&nn_cdf, q), at(&lr_cdf, q));
        println!("{:>8.0}% {:>11.2}% {:>11.2}%", q * 100.0, n * 100.0, l * 100.0);
        csv.rowf(&[q, n, l]).ok();
    }

    let n90 = at(&nn_cdf, 0.9);
    let l90 = at(&lr_cdf, 0.9);
    println!("\nNN p90 {:.2}% vs linreg p90 {:.2}% → {:.1}× lower \
              (paper: ~2× lower, 90% within 2.69%)",
             n90 * 100.0, l90 * 100.0, l90 / n90);
    assert!(n90 < l90 / 1.5, "NN must clearly beat linreg: {n90} vs {l90}");
    assert!(n90 < 0.10, "NN p90 error too high: {n90}");
    println!("fig13 OK — wrote results/fig13_predictor.csv");
}
