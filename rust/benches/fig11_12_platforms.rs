//! Paper Figs. 11 & 12 — scalability across heterogeneous edge
//! platforms: Jetson Nano / TX2 / Xavier NX, three models (YOLO-v5,
//! ResNet-18, TinyBERT), three schedulers.
//!
//! Expected shape (paper §V-D): BCEdge wins on every platform; richer
//! platforms yield higher utility / throughput and lower latency; the
//! cheapest model (res) benefits most.

use bcedge::coordinator::harness::{Experiment, SchedKind};
use bcedge::platform::PlatformSpec;
use bcedge::util::bench::{banner, Csv};
use bcedge::workload::models::ModelId;

fn main() {
    let platforms = PlatformSpec::scalability_set(); // nano, tx2, nx
    let kinds = [SchedKind::Sac, SchedKind::Tac, SchedKind::DeepRt];
    let models = vec![ModelId::Yolo, ModelId::Res, ModelId::Bert];
    let mut csv = Csv::create(
        "results/fig11_12_platforms.csv",
        "platform,scheduler,utility,peak_rps,mean_latency_ms").expect("csv");

    banner("Fig. 11 — utility per platform (yolo+res+bert, 30 rps)");
    println!("{:<12} {:>10} {:>10} {:>10}", "platform", "BCEdge", "TAC",
             "DeepRT");
    let mut fig12: Vec<(String, [f64; 3], [f64; 3])> = Vec::new();
    for p in &platforms {
        let mut utils = [0.0f64; 3];
        let mut rps = [0.0f64; 3];
        let mut lat = [0.0f64; 3];
        for (ki, kind) in kinds.iter().enumerate() {
            let mut e = Experiment::new(*kind);
            e.platform = p.clone();
            // Offered rate is fixed across platforms (paper protocol) at a
            // level the weakest board can partially absorb; the richer
            // boards convert the headroom into throughput/latency wins
            // (Fig. 12).
            e.rps = 2.0;
            e.models = Some(models.clone());
            e.horizon_s = 300.0;
            let m = e.run();
            let u = m.mean_utility(None);
            utils[ki] = if u.is_finite() { u } else { 0.0 };
            rps[ki] = m.throughput_rps(300.0 * 1e3);
            lat[ki] = m.mean_latency_ms(None);
            csv.row(&[p.name.to_string(), kind.label().into(),
                      format!("{:.4}", utils[ki]), format!("{:.2}", rps[ki]),
                      format!("{:.2}", lat[ki])]).ok();
        }
        println!("{:<12} {:>10.3} {:>10.3} {:>10.3}", p.name, utils[0],
                 utils[1], utils[2]);
        fig12.push((p.name.to_string(), rps, lat));
        // Shape: BCEdge beats the concurrency-less DeepRT on every
        // platform (the robust paper claim); BCEdge-vs-TAC reproduces as
        // parity-to-small-gaps — honest deltas in EXPERIMENTS.md.
        assert!(utils[0] > utils[2],
                "BCEdge must beat DeepRT on {}: {utils:?}", p.name);
    }

    banner("Fig. 12 — peak throughput (rps) / mean latency (ms) per platform");
    println!("{:<12} {:>22} {:>22} {:>22}", "platform",
             "BCEdge rps/lat", "TAC rps/lat", "DeepRT rps/lat");
    for (name, rps, lat) in &fig12 {
        println!("{:<12} {:>12.1}/{:>8.1} {:>12.1}/{:>8.1} {:>12.1}/{:>8.1}",
                 name, rps[0], lat[0], rps[1], lat[1], rps[2], lat[2]);
    }
    // Shape: richer platforms serve at least as much, with lower latency,
    // under BCEdge.
    let sac_rps: Vec<f64> = fig12.iter().map(|x| x.1[0]).collect();
    let sac_lat: Vec<f64> = fig12.iter().map(|x| x.2[0]).collect();
    assert!(sac_rps[2] >= sac_rps[0] * 0.95,
            "NX should serve at least Nano's rate under BCEdge: {sac_rps:?}");
    assert!(sac_lat[2] < sac_lat[0],
            "NX should be faster than Nano under BCEdge: {sac_lat:?}");
    println!("\nfig11/12 OK — wrote results/fig11_12_platforms.csv");
}
