//! Paper Figs. 8 & 9 — 3000-second serving timelines under BCEdge:
//! per-model throughput (Fig. 8, stacked) and mean end-to-end latency
//! (Fig. 9), both bucketed per 100 s.
//!
//! Expected shape: both curves ramp while the online SAC scheduler is
//! still exploring (paper: 0–1500 s) and then saturate once it has found
//! the per-model sweet spots.

use bcedge::coordinator::harness::{Experiment, SchedKind};
use bcedge::util::bench::{banner, Csv};
use bcedge::workload::models::ModelId;

fn main() {
    const HORIZON_S: f64 = 3000.0;
    const BUCKET_S: f64 = 100.0;

    banner("Figs. 8/9 — 3000 s timeline under BCEdge (virtual time, 30 rps)");
    let mut e = Experiment::new(SchedKind::Sac);
    e.horizon_s = HORIZON_S;
    let metrics = e.run();
    let timeline = metrics.timeline(BUCKET_S, HORIZON_S * 1e3);

    let mut csv = Csv::create(
        "results/fig08_09_timeline.csv",
        "t_s,model,throughput_rps,mean_latency_ms",
    )
    .expect("csv");

    println!("{:>6} | {:>44} | {:>44}", "t(s)",
             "Fig. 8: completions/s per model (stacked)",
             "Fig. 9: mean latency (ms) per model");
    println!("{:>6} | {}", "",
             "yolo   mob    res    eff    inc    bert  ".repeat(2));
    for (i, bucket) in timeline.iter().enumerate() {
        let t = (i as f64 + 1.0) * BUCKET_S;
        print!("{t:>6.0} |");
        for model in ModelId::all() {
            let rps = bucket.completed[model as usize] as f64 / BUCKET_S;
            print!(" {rps:>6.2}");
        }
        print!(" |");
        for model in ModelId::all() {
            let lat = bucket.mean_latency(model);
            print!(" {:>6.1}", if lat.is_finite() { lat } else { 0.0 });
            csv.row(&[format!("{t}"), model.name().into(),
                      format!("{:.3}",
                              bucket.completed[model as usize] as f64 / BUCKET_S),
                      format!("{:.3}", if lat.is_finite() { lat } else { 0.0 })])
                .ok();
        }
        println!();
    }

    // Shape: aggregate served rate in the final quarter must hold ≥85 %
    // of the offered rate (6 models × the harness default per-model rps).
    let offered = 6.0 * e.rps;
    let n = timeline.len();
    let first: f64 = timeline[0].total_completed() as f64 / BUCKET_S;
    let late: f64 = timeline[3 * n / 4..]
        .iter()
        .map(|b| b.total_completed() as f64)
        .sum::<f64>()
        / (BUCKET_S * (n - 3 * n / 4) as f64);
    println!("\nfirst-bucket rate {first:.1} rps; late mean {late:.1} rps (offered {offered:.0})");
    assert!(late >= 0.85 * offered, "scheduler failed to keep up late: {late}");
    println!("fig08/09 OK — wrote results/fig08_09_timeline.csv");
}
