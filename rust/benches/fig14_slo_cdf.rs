//! Paper Fig. 14 — cumulative distribution of per-window SLO-violation
//! rate at 30 rps, BCEdge with vs without the interference predictor.
//!
//! Expected shape (paper §V-E): the predictor cuts the violation-rate
//! ceiling (paper: ~9.2 % → ~4.1 % over 3000 s; we run 1500 s — the
//! timeline is stationary after the pretrained policy deploys).

use bcedge::coordinator::harness::{Experiment, SchedKind};
use bcedge::util::bench::{banner, Csv};
use bcedge::util::stats::ecdf;

fn main() {
    const HORIZON_S: f64 = 1500.0;
    banner("Fig. 14 — SLO-violation-rate CDF, predictor on vs off (30 rps)");

    let mut with = Experiment::new(SchedKind::Sac);
    with.horizon_s = HORIZON_S;
    with.use_predictor = true;
    let m_with = with.run();

    let mut without = Experiment::new(SchedKind::Sac);
    without.horizon_s = HORIZON_S;
    without.use_predictor = false;
    let m_without = without.run();

    let w = m_with.windowed_violation_rates(10.0, HORIZON_S * 1e3);
    let wo = m_without.windowed_violation_rates(10.0, HORIZON_S * 1e3);
    let cdf_w = ecdf(&w);
    let cdf_wo = ecdf(&wo);

    let mut csv = Csv::create("results/fig14_slo_cdf.csv",
                              "violation_rate,cdf_with,cdf_without")
        .expect("csv");
    println!("{:>12} {:>16} {:>16}", "viol rate", "CDF (with)", "CDF (without)");
    for q in [0.5, 0.75, 0.9, 0.95, 0.99, 1.0] {
        let vw = quantile(&cdf_w, q);
        let vwo = quantile(&cdf_wo, q);
        println!("p{:<11.0} {:>15.2}% {:>15.2}%", q * 100.0, vw * 100.0,
                 vwo * 100.0);
        csv.rowf(&[q, vw, vwo]).ok();
    }

    let overall_w = m_with.violation_rate();
    let overall_wo = m_without.violation_rate();
    println!("\noverall violation rate: with predictor {:.2}% | without {:.2}% \
              (paper: 4.1% vs 9.2%)",
             overall_w * 100.0, overall_wo * 100.0);
    assert!(overall_w <= overall_wo,
            "predictor must not hurt: {overall_w} vs {overall_wo}");
    println!("fig14 OK — wrote results/fig14_slo_cdf.csv");
}

fn quantile(cdf: &[(f64, f64)], q: f64) -> f64 {
    cdf.iter().find(|(_, p)| *p >= q).map(|(x, _)| *x).unwrap_or(
        cdf.last().map(|(x, _)| *x).unwrap_or(f64::NAN))
}
