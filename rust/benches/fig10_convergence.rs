//! Paper Fig. 10 — training convergence of the scheduling algorithms:
//! SAC (ours) vs PPO vs DDQN (DRL) and GA (heuristic), all inside the
//! BCEdge framework on the same scheduling environment.
//!
//! Expected shape: SAC reaches its asymptotic return fastest (paper:
//! 1.8×–3.7× faster); GA converges slowest / prematurely.

use bcedge::coordinator::sac_sched::SchedEnv;
use bcedge::coordinator::STATE_DIM;
use bcedge::platform::PlatformSpec;
use bcedge::rl::ac::{AcConfig, ActorCritic};
use bcedge::rl::ddqn::{Ddqn, DdqnConfig};
use bcedge::rl::env::{train_episodes, Agent, Env};
use bcedge::rl::ga::{Ga, GaConfig};
use bcedge::rl::ppo::{Ppo, PpoConfig};
use bcedge::rl::sac::{DiscreteSac, SacConfig};
use bcedge::rl::ActionSpace;
use bcedge::util::bench::{banner, Csv};
use bcedge::util::rng::Pcg32;

const EPISODES: usize = 60;
const EP_LEN: usize = 64;

fn fresh_env() -> SchedEnv {
    // Moderate load (10 rps/model): the regime where scheduling decisions
    // are state-dependent. At saturation every slot wants the max batch,
    // which even a linear policy nails — no convergence signal.
    let mut env = SchedEnv::new(ActionSpace::standard(), 10.0,
                                PlatformSpec::xavier_nx());
    env.episode_len = EP_LEN;
    env
}

/// Train one agent; return per-episode mean returns.
fn run_agent(agent: &mut dyn Agent, seed: u64) -> Vec<f32> {
    let mut env = fresh_env();
    let mut rng = Pcg32::seeded(seed);
    train_episodes(&mut env, agent, EPISODES, EP_LEN, &mut rng)
        .into_iter()
        .map(|(ret, _)| ret)
        .collect()
}

/// Final-plateau return (mean of the last 10 episodes).
fn plateau(returns: &[f32]) -> f32 {
    let tail = &returns[returns.len() - 10..];
    tail.iter().sum::<f32>() / tail.len() as f32
}

/// Episodes until the 5-episode moving average reaches `target`.
/// Measuring against a COMMON target (the best plateau across
/// algorithms) is what penalizes premature convergence: an algorithm
/// that plateaus low (the paper's GA critique) never reaches it.
fn episodes_to_reach(returns: &[f32], target: f32) -> usize {
    for i in 4..returns.len() {
        let ma: f32 = returns[i - 4..=i].iter().sum::<f32>() / 5.0;
        if ma >= target {
            return i + 1;
        }
    }
    returns.len() + 1 // never converged within budget
}

fn main() {
    banner("Fig. 10 — convergence of SAC / PPO / DDQN / GA on the scheduling env");
    let space = ActionSpace::standard();
    let n_act = space.len();
    let mut rng = Pcg32::seeded(1010);

    let mut sac = DiscreteSac::new(
        STATE_DIM, n_act,
        // Offline training: gradient step every transition (the paper's
        // Algorithm 1); the amortized update_every=4 is a serving-path
        // optimization only.
        SacConfig { warmup: 128, batch_size: 64, update_every: 1,
                    ..Default::default() },
        &mut rng);
    let mut ppo = Ppo::new(STATE_DIM, n_act, PpoConfig::default(), &mut rng);
    let mut ddqn = Ddqn::new(
        STATE_DIM, n_act,
        DdqnConfig { eps_decay_steps: 1500, ..Default::default() }, &mut rng);
    let mut ac = ActorCritic::new(STATE_DIM, n_act, AcConfig::default(), &mut rng);

    let sac_r = run_agent(&mut sac, 1);
    let ppo_r = run_agent(&mut ppo, 2);
    let ddqn_r = run_agent(&mut ddqn, 3);
    let ac_r = run_agent(&mut ac, 4);

    // GA: generation-wise evolution on the same env; sample its deployed
    // policy's return per generation for a comparable curve.
    let mut env = fresh_env();
    let mut ga_rng = Pcg32::seeded(5);
    let mut ga = Ga::new(STATE_DIM, n_act,
                         GaConfig { max_steps: EP_LEN, ..Default::default() },
                         &mut ga_rng);
    let mut ga_r = Vec::with_capacity(EPISODES);
    for _ in 0..EPISODES {
        ga.evolve(&mut env, &mut ga_rng);
        // Same metric as the DRL agents: ONE fresh evaluation episode of
        // the deployed (best-genome) policy — not the max-so-far fitness,
        // which inflates under evaluation noise.
        let ret = train_episodes(&mut env, &mut ga, 1, EP_LEN, &mut ga_rng)[0].0;
        ga_r.push(ret);
    }

    let mut csv = Csv::create("results/fig10_convergence.csv",
                              "episode,sac,ppo,ddqn,tac,ga").expect("csv");
    println!("{:>8} {:>10} {:>10} {:>10} {:>10} {:>10}",
             "episode", "SAC", "PPO", "DDQN", "TAC", "GA");
    for i in 0..EPISODES {
        if i % 5 == 0 || i + 1 == EPISODES {
            println!("{:>8} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1}",
                     i + 1, sac_r[i], ppo_r[i], ddqn_r[i], ac_r[i], ga_r[i]);
        }
        csv.rowf(&[(i + 1) as f64, sac_r[i] as f64, ppo_r[i] as f64,
                   ddqn_r[i] as f64, ac_r[i] as f64, ga_r[i] as f64]).ok();
    }

    // Common convergence bar: 90 % of the best plateau achieved by any
    // algorithm. Premature plateaus (GA) never reach it.
    let best_plateau = [plateau(&sac_r), plateau(&ppo_r), plateau(&ddqn_r),
                        plateau(&ac_r), plateau(&ga_r)]
        .into_iter()
        .fold(f32::MIN, f32::max);
    let bar = 0.9 * best_plateau;
    let conv = [("SAC", episodes_to_reach(&sac_r, bar), plateau(&sac_r)),
                ("PPO", episodes_to_reach(&ppo_r, bar), plateau(&ppo_r)),
                ("DDQN", episodes_to_reach(&ddqn_r, bar), plateau(&ddqn_r)),
                ("TAC", episodes_to_reach(&ac_r, bar), plateau(&ac_r)),
                ("GA", episodes_to_reach(&ga_r, bar), plateau(&ga_r))];
    println!("\nepisodes to reach 90% of the best plateau ({bar:.0}):");
    for (name, ep, pl) in conv {
        let speedup = ep as f64 / conv[0].1 as f64;
        let tag = if ep > EPISODES { "never".to_string() } else { format!("{ep}") };
        println!("  {name:<5} {tag:>6}  ({speedup:.1}× vs SAC)  plateau {pl:.0}");
    }
    println!("(paper: SAC converges 1.8×–3.7× faster than baselines)");
    assert!(conv[0].1 <= EPISODES, "SAC itself must converge");
    println!("fig10 OK — wrote results/fig10_convergence.csv");
}
