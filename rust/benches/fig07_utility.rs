//! Paper Fig. 7 — normalized utility of BCEdge vs TAC vs DeepRT across
//! the six-model zoo at 30 rps on (simulated) Xavier NX.
//!
//! Expected shape: BCEdge highest for every model; paper reports +37 %
//! over DeepRT and +25 % over TAC on average.

use bcedge::coordinator::harness::{Experiment, SchedKind};
use bcedge::util::bench::{banner, Csv};
use bcedge::workload::models::ModelId;

fn main() {
    banner("Fig. 7 — normalized utility per model (30 rps, Xavier NX)");
    let kinds = [SchedKind::Sac, SchedKind::Tac, SchedKind::DeepRt];
    let mut utilities = vec![[0.0f64; 3]; 6];

    for (ki, kind) in kinds.iter().enumerate() {
        let mut e = Experiment::new(*kind);
        e.horizon_s = 400.0;
        let m = e.run();
        for model in ModelId::all() {
            let u = m.mean_utility(Some(model));
            utilities[model as usize][ki] = if u.is_finite() { u } else { 0.0 };
        }
    }

    // Normalize per model by the max across schedulers (paper's y-axis).
    let mut csv = Csv::create("results/fig07_utility.csv",
                              "model,bcedge,tac,deeprt").expect("csv");
    println!("{:<6} {:>10} {:>10} {:>10}", "model", "BCEdge", "TAC", "DeepRT");
    let mut mean = [0.0f64; 3];
    for model in ModelId::all() {
        let row = utilities[model as usize];
        let max = row.iter().cloned().fold(f64::MIN, f64::max).max(1e-9);
        let norm: Vec<f64> = row.iter().map(|u| u / max).collect();
        println!("{:<6} {:>10.3} {:>10.3} {:>10.3}",
                 model.name(), norm[0], norm[1], norm[2]);
        csv.row(&[model.name().to_string(), format!("{:.4}", norm[0]),
                  format!("{:.4}", norm[1]), format!("{:.4}", norm[2])]).ok();
        for k in 0..3 {
            mean[k] += norm[k] / 6.0;
        }
    }
    println!("{:<6} {:>10.3} {:>10.3} {:>10.3}", "mean", mean[0], mean[1], mean[2]);
    println!("\nBCEdge vs DeepRT: +{:.1}% | BCEdge vs TAC: +{:.1}%  (paper: +37%, +25%)",
             100.0 * (mean[0] / mean[2] - 1.0),
             100.0 * (mean[0] / mean[1] - 1.0));
    // Shape assertions (see EXPERIMENTS.md for the honest deltas): BCEdge
    // must strictly beat the concurrency-less DeepRT; against TAC our
    // simulator reproduces parity-to-small-gains, not the paper's +25 %
    // (both learners converge on this smoother reward surface), so the
    // assert allows a statistical tie.
    assert!(mean[0] > mean[2], "BCEdge must beat DeepRT: {mean:?}");
    assert!(mean[0] >= 0.97 * mean[1], "BCEdge far behind TAC: {mean:?}");
    println!("fig07 OK — wrote results/fig07_utility.csv");
}
