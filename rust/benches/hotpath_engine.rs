//! Hot-path benchmark for the scheduling engine (perf PR #1) — the
//! trajectory anchor for every future perf PR.
//!
//! Four sections, all on the shared `util::bench` harness:
//!
//! 1. **sim serving** — rounds/sec and µs/decision for the full engine
//!    loop (SAC learning on, predictor on) at three offered loads;
//! 2. **component before/after** — the seed implementations survive as
//!    public oracles/wrappers (`*_naive_ms`, `mean_inflation_naive`,
//!    `forward_cache`/`backward`, `step`, `predict_alloc`,
//!    `train_step_alloc`), so the allocating "before" path and the
//!    buffer-reusing "after" path are measured side by side in the same
//!    binary — including the PR #2 finishes: `step_into`'s caller-owned
//!    outcome buffer and the predictor's scratch predict/train paths;
//! 3. **SAC update step** — µs per `update_batch` on the paper's network
//!    shape, plus the allocating fwd+bwd core it replaced;
//! 4. **router throughput** — front-end routing decisions/sec against a
//!    gossiped 12-node [`ClusterView`] at 1/4/16 router shards, with the
//!    deduplicating result cache off and on, while a publisher thread
//!    keeps re-publishing slots (the contention the sharded design must
//!    shrug off: per-decision cost should stay flat as shards grow);
//! 5. **telemetry overhead** — the section-1 serving run with request
//!    tracing off / 1-in-64 sampled / tracing every request, so the
//!    observability off-switch's zero-cost claim (and full tracing's
//!    price) is a measured number, not an assertion;
//! 6. **llm session serving** — the autoregressive session tier on the
//!    virtual cluster: session decode steps/sec plus TTFT and TPOT p95
//!    at 2 and 8 decode steps, with link-contention pricing off and on.
//!
//! Writes `BENCH_hotpath.json` at the repo root (falling back to the
//! crate root when run elsewhere). Compare across commits by re-running
//! `cargo bench --bench hotpath_engine` on each.

use bcedge::cluster::{digest_for, CacheConfig, CacheLookup, ClusterView,
                      NodeView, ResultCache, RoutePolicy, Router, ViewReader};
use bcedge::coordinator::baselines::FixedScheduler;
use bcedge::coordinator::queue::ModelQueue;
use bcedge::coordinator::sac_sched;
use bcedge::coordinator::{Engine, EngineConfig};
use bcedge::nn::mlp::{BackwardScratch, ForwardCache};
use bcedge::nn::tensor::Mat;
use bcedge::nn::Mlp;
use bcedge::platform::PlatformSim;
use bcedge::predictor::{AdmissionMode, AdmissionQuantile,
                        InterferencePredictor, PredictorSample};
use bcedge::serve::AdmissionConfig;
use bcedge::profiler::{ProfileSample, Profiler};
use bcedge::rl::env::{Agent, Transition};
use bcedge::rl::sac::{DiscreteSac, SacConfig};
use bcedge::rl::ActionSpace;
use bcedge::runtime::executor::SimDispatcher;
use bcedge::serve::GaugeSnapshot;
use bcedge::util::bench::{banner, time_fn};
use bcedge::util::json::{arr, num, obj, s, Json};
use bcedge::util::rng::Pcg32;
use bcedge::util::time::VirtualClock;
use bcedge::workload::models::ModelId;
use bcedge::workload::request::Request;
use bcedge::workload::PoissonGenerator;

/// One serving run: SAC learning online, predictor on — the full
/// decision + learning + dispatch + accounting path.
fn serving_run(rps_per_model: f64, horizon_ms: f64) -> (u64, f64) {
    let clock = VirtualClock::new();
    let dispatcher = SimDispatcher::new(PlatformSim::xavier_nx(), clock);
    let mut engine = Engine::new(dispatcher, EngineConfig::default());
    let mut gen = PoissonGenerator::new(rps_per_model * 6.0, 0xBE);
    engine.submit(gen.generate_horizon(horizon_ms));
    let mut rng = Pcg32::seeded(0x5AC);
    let mut sched = sac_sched::sac(ActionSpace::standard(), &mut rng);
    let t0 = std::time::Instant::now();
    let slots = engine.run(&mut sched, horizon_ms);
    (slots, t0.elapsed().as_secs_f64())
}

/// The serving run with an [`bcedge::telemetry::EngineTracer`] attached
/// at `1/sample` (0 = tracing off): what observability costs the hot
/// path. Identical workload and seeds to [`serving_run`].
fn serving_run_traced(rps_per_model: f64, horizon_ms: f64, sample: u64)
                      -> (u64, f64) {
    use bcedge::telemetry::{EngineTracer, TelemetryConfig};
    let clock = VirtualClock::new();
    let dispatcher = SimDispatcher::new(PlatformSim::xavier_nx(), clock);
    let mut engine = Engine::new(dispatcher, EngineConfig::default());
    if sample > 0 {
        let tcfg = TelemetryConfig {
            trace_sample: sample,
            ..Default::default()
        };
        engine.set_tracer(Some(EngineTracer::new(&tcfg, 0)));
    }
    let mut gen = PoissonGenerator::new(rps_per_model * 6.0, 0xBE);
    engine.submit(gen.generate_horizon(horizon_ms));
    let mut rng = Pcg32::seeded(0x5AC);
    let mut sched = sac_sched::sac(ActionSpace::standard(), &mut rng);
    let t0 = std::time::Instant::now();
    let slots = engine.run(&mut sched, horizon_ms);
    (slots, t0.elapsed().as_secs_f64())
}

/// Publish every slot of `view` active with heterogeneous backlogs, as
/// the gossip thread does in `run_cluster`.
fn publish_synthetic(view: &ClusterView, t_ms: f64) {
    for i in 0..view.len() {
        let mut g = GaugeSnapshot::default();
        g.total_backlog_ms = 7.0 * i as f64;
        for e in g.est_batch_ms.iter_mut() {
            *e = 10.0 + i as f64;
        }
        view.publish(i, true, g, t_ms);
    }
}

/// One router-throughput run: `shards` front-end shards each draining
/// `total / shards` requests against a live gossiped `view` (a publisher
/// thread keeps bumping epochs underneath), with the result cache
/// optionally in front. Returns (wall seconds, requests, cache-served).
fn router_run(view: &ClusterView, shards: usize, cache: Option<&ResultCache>,
              total: u64) -> (f64, u64, u64) {
    use std::sync::atomic::{AtomicBool, Ordering};
    let per_shard = total / shards as u64;
    let stop = AtomicBool::new(false);
    let model = ModelId::Res;
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        let publisher = scope.spawn(|| {
            let mut tick = 0u64;
            while !stop.load(Ordering::Relaxed) {
                tick += 1;
                publish_synthetic(view, tick as f64);
                std::thread::sleep(std::time::Duration::from_micros(500));
            }
        });
        let workers: Vec<_> = (0..shards)
            .map(|s| {
                scope.spawn(move || {
                    let mut reader = ViewReader::new(view);
                    let mut router = Router::with_stream(
                        RoutePolicy::PowerOfTwoChoices, 0xBE_7C, s as u64);
                    let mut views = Vec::with_capacity(view.len());
                    for j in 0..per_shard {
                        let idx = s as u64 * per_shard + j;
                        let mut lead = None;
                        if let Some(c) = cache {
                            let digest = digest_for(0xD16, idx, 0.5);
                            match c.lookup(model, digest, idx as f64) {
                                CacheLookup::Hit
                                | CacheLookup::Coalesced => continue,
                                CacheLookup::Lead => lead = Some(digest),
                            }
                        }
                        reader.sync(view);
                        views.clear();
                        for n in 0..reader.len() {
                            let p = reader.get(n);
                            views.push(NodeView {
                                active: p.active,
                                rtt_ms: 1.0 + n as f64,
                                backlog_ms: p.gauges.total_backlog_ms,
                                service_est_ms: p.gauges
                                    .service_est_ms(model),
                                predicted_e2e_ms: f64::NAN,
                                tx_est_ms: 0.0,
                            });
                        }
                        let pick = router.route(&views, 1e9);
                        std::hint::black_box(&pick);
                        if let (Some(c), Some(digest), Ok(_)) =
                            (cache, lead, pick)
                        {
                            // Fill immediately: the steady state where
                            // popular digests are resident.
                            c.commit_leader(model, digest, idx);
                            c.on_completed(idx, idx as f64);
                        }
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        publisher.join().unwrap();
    });
    let requests = per_shard * shards as u64;
    let served = cache.map_or(0, |c| c.stats().served());
    (t0.elapsed().as_secs_f64(), requests, served)
}

fn main() {
    banner("hot-path engine benchmark (perf PR #1)");
    let mut sections: Vec<(&str, Json)> = Vec::new();

    // ---------------------------------------------------------------
    // 1. Sim serving throughput at three load levels.
    // ---------------------------------------------------------------
    banner("sim serving (SAC + predictor, virtual horizon 120 s)");
    let mut serving = Vec::new();
    for rps in [10.0, 30.0, 90.0] {
        let (slots, wall_s) = serving_run(rps, 120_000.0);
        let slots_per_sec = slots as f64 / wall_s.max(1e-9);
        let us_per_slot = wall_s * 1e6 / slots.max(1) as f64;
        println!(
            "{rps:>5.0} rps/model  {slots:>7} slots  {slots_per_sec:>12.0} slots/s  \
             {us_per_slot:>8.2} µs/slot"
        );
        serving.push(obj(vec![
            ("rps_per_model", num(rps)),
            ("slots", num(slots as f64)),
            ("slots_per_sec", num(slots_per_sec)),
            ("us_per_slot", num(us_per_slot)),
        ]));
    }
    sections.push(("sim_serving", arr(serving)));

    // ---------------------------------------------------------------
    // 2. Component before/after: queue + profiler aggregates.
    // ---------------------------------------------------------------
    banner("O(1) aggregates vs seed O(n) scans");
    let mut q = ModelQueue::new();
    let mut rng = Pcg32::seeded(7);
    for id in 0..2048u64 {
        let mut r = Request::new(id, ModelId::Res, rng.f64() * 1000.0);
        r.slo_ms = 20.0 + rng.f64() * 150.0;
        q.push(r);
    }
    let t_naive = time_fn("queue min_deadline naive (n=2048)", 100, 2000, || {
        std::hint::black_box(q.min_deadline_naive_ms());
    });
    let t_roll = time_fn("queue min_deadline rolling", 100, 2000, || {
        std::hint::black_box(q.min_deadline_ms());
    });
    println!("{}", t_naive.row());
    println!("{}", t_roll.row());

    let mut prof = Profiler::new(512);
    for i in 0..512 {
        prof.record(ProfileSample {
            t_ms: i as f64,
            model: ModelId::from_index(i % 6),
            batch: 4,
            concurrency: 2,
            latency_ms: 25.0,
            completed: 4,
            compute_demand: 1.0,
            memory_pressure: 0.4,
            active_instances: 2,
            inflation: 1.2,
        });
    }
    let p_naive = time_fn("profiler mean_inflation naive (w=512)", 100, 2000,
                          || {
        std::hint::black_box(prof.mean_inflation_naive());
    });
    let p_roll = time_fn("profiler mean_inflation rolling", 100, 2000, || {
        std::hint::black_box(prof.mean_inflation());
    });
    println!("{}", p_naive.row());
    println!("{}", p_roll.row());
    sections.push((
        "aggregates",
        obj(vec![
            ("queue_naive_us", num(t_naive.mean_us)),
            ("queue_rolling_us", num(t_roll.mean_us)),
            ("queue_speedup", num(t_naive.mean_us / t_roll.mean_us.max(1e-9))),
            ("profiler_naive_us", num(p_naive.mean_us)),
            ("profiler_rolling_us", num(p_roll.mean_us)),
            ("profiler_speedup",
             num(p_naive.mean_us / p_roll.mean_us.max(1e-9))),
        ]),
    ));

    // ---------------------------------------------------------------
    // 2b. Round loop: caller-owned outcome buffer (step_into) vs the
    //     allocating per-round outcome vec (step) — the last piece of
    //     the zero-allocation story. Identical engines + workloads;
    //     predictor on, so the alloc-free predict probes are included.
    // ---------------------------------------------------------------
    banner("engine round: step_into (reused buffer) vs step (allocating)");
    let mk_engine = || {
        let clock = VirtualClock::new();
        let dispatcher = SimDispatcher::new(PlatformSim::xavier_nx(), clock);
        let mut engine = Engine::new(
            dispatcher,
            EngineConfig { learn: false, ..Default::default() },
        );
        let mut gen = PoissonGenerator::new(180.0, 0xE2);
        engine.submit(gen.generate_horizon(600_000.0));
        engine
    };
    let mut e_into = mk_engine();
    let mut s_into = FixedScheduler { batch: 4, m_c: 2 };
    let mut outcome_buf = Vec::new();
    let t_step_into = time_fn("engine step_into (reused outcomes)", 50, 1500,
                              || {
        std::hint::black_box(e_into.step_into(&mut s_into, &mut outcome_buf));
    });
    let mut e_alloc = mk_engine();
    let mut s_alloc = FixedScheduler { batch: 4, m_c: 2 };
    let t_step_alloc = time_fn("engine step (fresh outcome vec)", 50, 1500,
                               || {
        std::hint::black_box(e_alloc.step(&mut s_alloc));
    });
    println!("{}", t_step_into.row());
    println!("{}", t_step_alloc.row());
    assert!(e_into.total_queued() > 0 && e_alloc.total_queued() > 0,
            "workload exhausted mid-measurement; lengthen the horizon");
    sections.push((
        "engine_step",
        obj(vec![
            ("step_into_us", num(t_step_into.mean_us)),
            ("step_alloc_us", num(t_step_alloc.mean_us)),
            ("step_speedup",
             num(t_step_alloc.mean_us / t_step_into.mean_us.max(1e-9))),
        ]),
    ));

    // ---------------------------------------------------------------
    // 2c. Predictor veto probe + training step: scratch vs seed alloc
    //     paths (both proven bit-identical by the predictor tests).
    // ---------------------------------------------------------------
    banner("interference predictor: scratch vs allocating oracles");
    let mut prng = Pcg32::seeded(0xF1);
    let mut pred = InterferencePredictor::new(&mut prng);
    for i in 0..512 {
        pred.observe(PredictorSample {
            memory_pressure: 0.3 + 0.4 * ((i % 7) as f64 / 7.0),
            compute_demand: 1.0 + (i % 5) as f64,
            active_instances: 1 + i % 6,
            concurrency: 1 + i % 4,
            batch: 1 << (i % 6),
            inflation: 1.0 + (i % 9) as f64 * 0.1,
        });
    }
    pred.fit(100, &mut prng);
    let probe = PredictorSample {
        memory_pressure: 0.5,
        compute_demand: 2.5,
        active_instances: 3,
        concurrency: 2,
        batch: 8,
        inflation: 1.0,
    };
    let t_pred = time_fn("predict scratch (veto probe)", 200, 4000, || {
        std::hint::black_box(pred.predict(&probe));
    });
    let t_pred_alloc = time_fn("predict SEED alloc path", 200, 4000, || {
        std::hint::black_box(pred.predict_alloc(&probe));
    });
    let mut train_rng = Pcg32::seeded(0xF2);
    let t_train = time_fn("train_step scratch (batch 64)", 20, 300, || {
        std::hint::black_box(pred.train_step(&mut train_rng));
    });
    let t_train_alloc =
        time_fn("train_step SEED alloc path (batch 64)", 20, 300, || {
            std::hint::black_box(pred.train_step_alloc(&mut train_rng));
        });
    println!("{}", t_pred.row());
    println!("{}", t_pred_alloc.row());
    println!("{}", t_train.row());
    println!("{}", t_train_alloc.row());
    sections.push((
        "predictor",
        obj(vec![
            ("predict_us", num(t_pred.mean_us)),
            ("predict_alloc_us", num(t_pred_alloc.mean_us)),
            ("predict_speedup",
             num(t_pred_alloc.mean_us / t_pred.mean_us.max(1e-9))),
            ("train_step_us", num(t_train.mean_us)),
            ("train_step_alloc_us", num(t_train_alloc.mean_us)),
            ("train_step_speedup",
             num(t_train_alloc.mean_us / t_train.mean_us.max(1e-9))),
        ]),
    ));

    // ---------------------------------------------------------------
    // 2d. Headroom admission pricing (predictive PR): what one ingress
    //     decision costs on the snapshot formula vs the predictive
    //     headroom path (warm mean / warm p95 / cold fallback). The
    //     predictive path is pure float arithmetic over published gauge
    //     lanes — it must price within the same order as snapshot, or
    //     the per-request admission gate becomes the new hot spot.
    // ---------------------------------------------------------------
    banner("headroom admission: snapshot vs predictive pricing");
    let snap_cfg = AdmissionConfig::default();
    let warm_cfg = AdmissionConfig {
        mode: AdmissionMode::Predictive,
        ..Default::default()
    };
    let p95_cfg = AdmissionConfig {
        mode: AdmissionMode::Predictive,
        quantile: AdmissionQuantile::P95,
        ..Default::default()
    };
    let (queue, mean_ms, isolated_ms, slack_ms) = (24usize, 18.0, 15.0, 400.0);
    let h_snap = time_fn("admission snapshot decide", 200, 4000, || {
        std::hint::black_box(
            snap_cfg.decide(queue, mean_ms, isolated_ms, slack_ms));
    });
    let h_warm = time_fn("admission predictive (warm, mean)", 200, 4000, || {
        std::hint::black_box(warm_cfg.decide_predictive(
            queue, mean_ms, isolated_ms, slack_ms, 1.35, 1.6));
    });
    let h_p95 = time_fn("admission predictive (warm, p95)", 200, 4000, || {
        std::hint::black_box(p95_cfg.decide_predictive(
            queue, mean_ms, isolated_ms, slack_ms, 1.35, 1.6));
    });
    let h_cold = time_fn("admission predictive (cold fallback)", 200, 4000,
                         || {
        std::hint::black_box(warm_cfg.decide_predictive(
            queue, mean_ms, isolated_ms, slack_ms, f64::NAN, f64::NAN));
    });
    println!("{}", h_snap.row());
    println!("{}", h_warm.row());
    println!("{}", h_p95.row());
    println!("{}", h_cold.row());
    sections.push((
        "predictor_headroom",
        obj(vec![
            ("snapshot_us", num(h_snap.mean_us)),
            ("predictive_mean_us", num(h_warm.mean_us)),
            ("predictive_p95_us", num(h_p95.mean_us)),
            ("predictive_cold_fallback_us", num(h_cold.mean_us)),
            ("predictive_over_snapshot",
             num(h_warm.mean_us / h_snap.mean_us.max(1e-9))),
        ]),
    ));

    // ---------------------------------------------------------------
    // 3. NN core + SAC update: allocating seed path vs reused buffers.
    // ---------------------------------------------------------------
    banner("NN fwd+bwd: allocating (seed) vs buffer-reusing");
    let mut rng = Pcg32::seeded(21);
    // Paper shape: STATE_DIM-ish input, 128/64 hidden, action-grid output.
    let net = Mlp::new(&[16, 128, 64, 24], &mut rng);
    let x = Mat::kaiming(64, 16, &mut rng);
    let d = Mat::kaiming(64, 24, &mut rng);
    let t_alloc = time_fn("fwd_cache+bwd allocating (batch 64)", 20, 200, || {
        let cache = net.forward_cache(&x);
        std::hint::black_box(net.backward(&cache, &d));
    });
    let mut cache = ForwardCache::new();
    let mut grads = Vec::new();
    let mut scratch = BackwardScratch::new();
    let t_into = time_fn("fwd_cache+bwd reused (batch 64)", 20, 200, || {
        net.forward_cache_into(&x, &mut cache);
        net.backward_into(&cache, &d, &mut grads, &mut scratch);
        std::hint::black_box(&grads);
    });
    println!("{}", t_alloc.row());
    println!("{}", t_into.row());

    banner("full SAC update step: seed oracle vs scratch path");
    let mk_sac = || {
        let mut rng = Pcg32::seeded(33);
        let cfg =
            SacConfig { warmup: 64, batch_size: 64, ..Default::default() };
        let mut sac = DiscreteSac::new(16, 24, cfg, &mut rng);
        let mut feed = Pcg32::seeded(36);
        for _ in 0..512 {
            let st: Vec<f32> =
                (0..16).map(|_| feed.f32() * 2.0 - 1.0).collect();
            let nx: Vec<f32> =
                (0..16).map(|_| feed.f32() * 2.0 - 1.0).collect();
            let a = sac.act(&st, &mut feed, false);
            sac.observe(Transition {
                state: st,
                action: a,
                reward: feed.f32() * 2.0 - 1.0,
                next_state: nx,
                done: false,
            });
        }
        sac
    };
    // The seed's allocating update survives as DiscreteSac::
    // update_batch_alloc (bit-identical math, proven by the sac tests),
    // so the >=2x acceptance target is measured directly here.
    let mut sac_seed = mk_sac();
    let mut rng_s = Pcg32::seeded(34);
    let t_update_seed =
        time_fn("sac update SEED alloc path (batch 64)", 20, 300, || {
            std::hint::black_box(sac_seed.update_batch_alloc(&mut rng_s));
        });
    let mut sac = mk_sac();
    let mut rng_u = Pcg32::seeded(34);
    let t_update = time_fn("sac update scratch path (batch 64)", 20, 300, || {
        std::hint::black_box(sac.update_batch(&mut rng_u));
    });
    println!("{}", t_update_seed.row());
    println!("{}", t_update.row());
    let mut rng_a = Pcg32::seeded(35);
    let probe: Vec<f32> = (0..16).map(|_| rng_a.f32()).collect();
    let t_act = time_fn("sac act (1 decision)", 100, 2000, || {
        std::hint::black_box(sac.act(&probe, &mut rng_a, false));
    });
    println!("{}", t_act.row());
    sections.push((
        "nn_sac",
        obj(vec![
            ("fwd_bwd_alloc_us", num(t_alloc.mean_us)),
            ("fwd_bwd_reused_us", num(t_into.mean_us)),
            ("fwd_bwd_speedup",
             num(t_alloc.mean_us / t_into.mean_us.max(1e-9))),
            ("sac_update_seed_us", num(t_update_seed.mean_us)),
            ("sac_update_us", num(t_update.mean_us)),
            ("sac_update_speedup_vs_seed",
             num(t_update_seed.mean_us / t_update.mean_us.max(1e-9))),
            ("sac_act_us", num(t_act.mean_us)),
        ]),
    ));

    // ---------------------------------------------------------------
    // 4. Sharded front-end routing throughput (PR #6): decisions/sec
    //    from a gossiped 12-node view at 1/4/16 shards, cache off/on.
    //    The sharded design's whole claim is that per-request cost
    //    stays flat as shards grow (no shared locks on the serving
    //    path); the flatness ratio below is that claim, measured.
    // ---------------------------------------------------------------
    banner("sharded front-end routing (gossiped 12-node view, p2c)");
    const FE_NODES: usize = 12;
    const FE_REQUESTS: u64 = 1 << 20;
    let fe_view = ClusterView::new(FE_NODES);
    publish_synthetic(&fe_view, 0.0);
    let mut sweep = Vec::new();
    let mut thr_uncached = std::collections::HashMap::new();
    for shards in [1usize, 4, 16] {
        for cached in [false, true] {
            let cache = cached.then(|| {
                ResultCache::new(CacheConfig {
                    ttl_ms: 1e9,
                    capacity: 65_536,
                })
            });
            let (wall_s, requests, served) =
                router_run(&fe_view, shards, cache.as_ref(), FE_REQUESTS);
            let rps = requests as f64 / wall_s.max(1e-9);
            let ns_per_req = wall_s * 1e9 / requests.max(1) as f64;
            if !cached {
                thr_uncached.insert(shards, rps);
            }
            println!(
                "{shards:>3} shard(s)  cache {}  {requests:>8} reqs  \
                 {rps:>12.0} req/s  {ns_per_req:>8.1} ns/req  \
                 {served:>7} cache-served",
                if cached { "on " } else { "off" }
            );
            sweep.push(obj(vec![
                ("shards", num(shards as f64)),
                ("cache", s(if cached { "on" } else { "off" })),
                ("requests", num(requests as f64)),
                ("requests_per_sec", num(rps)),
                ("ns_per_request", num(ns_per_req)),
                ("cache_served", num(served as f64)),
            ]));
        }
    }
    // Aggregate throughput at 16 shards over 1 shard, cache off. >= ~1
    // means the serving path added no shared-state penalty; > 1 is the
    // parallel speedup the runner's cores allow.
    let flatness = thr_uncached.get(&16).copied().unwrap_or(0.0)
        / thr_uncached.get(&1).copied().unwrap_or(1.0).max(1e-9);
    println!("throughput ratio 16/1 shards (cache off): {flatness:.2}x");
    sections.push((
        "router_throughput",
        obj(vec![
            ("nodes", num(FE_NODES as f64)),
            ("requests_per_config", num(FE_REQUESTS as f64)),
            ("sweep", arr(sweep)),
            ("throughput_ratio_16_over_1", num(flatness)),
        ]),
    ));

    // ---------------------------------------------------------------
    // 5. Telemetry overhead (observability PR): the same full serving
    //    run with tracing off / 1-in-64 sampled / every request. The
    //    off row IS section 1's configuration (tracer = None), so the
    //    sampled and full rows price the id-keyed sampling gate and the
    //    span bookkeeping against it.
    // ---------------------------------------------------------------
    banner("telemetry overhead (serving run: tracing off/sampled/full)");
    let mut tele = Vec::new();
    let mut base_sps = 0.0f64;
    for (label, sample) in [("off", 0u64), ("sampled_64", 64), ("full", 1)]
    {
        let (slots, wall_s) = serving_run_traced(30.0, 120_000.0, sample);
        let sps = slots as f64 / wall_s.max(1e-9);
        if sample == 0 {
            base_sps = sps;
        }
        let overhead_pct = if sample == 0 {
            0.0
        } else {
            100.0 * (base_sps / sps.max(1e-9) - 1.0)
        };
        println!(
            "{label:>10}  {slots:>7} slots  {sps:>12.0} slots/s  \
             overhead {overhead_pct:>6.2}%"
        );
        tele.push(obj(vec![
            ("mode", s(label)),
            ("trace_sample", num(sample as f64)),
            ("slots", num(slots as f64)),
            ("slots_per_sec", num(sps)),
            ("overhead_pct_vs_off", num(overhead_pct)),
        ]));
    }
    sections.push(("telemetry_overhead", arr(tele)));

    // ---------------------------------------------------------------
    // 6. LLM session serving (session-tier PR): the virtual cluster
    //    running multi-round sessions with dual TTFT/TPOT SLOs, at 2
    //    and 8 decode steps, with contention pricing off and on. Steps
    //    are spawned inside the event loop, so steps/sec prices the
    //    whole re-enqueue seam (outcome scan + spawn + link charge +
    //    delivery), not just arithmetic.
    // ---------------------------------------------------------------
    banner("llm session serving (virtual cluster, dual TTFT/TPOT SLOs)");
    use bcedge::cluster::{ClusterConfig, FrontEndConfig, NodeSpec,
                          run_cluster};
    use bcedge::platform::PlatformSpec;
    use bcedge::serve::{ClockKind, LoadGenConfig, SchedulerSpec, ServeConfig};
    use bcedge::workload::session::step_of;
    use bcedge::workload::SessionSpec;
    let p95 = |mut v: Vec<f64>| -> f64 {
        if v.is_empty() {
            return 0.0;
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[(v.len() - 1) * 95 / 100]
    };
    let mut llm = Vec::new();
    for decode_steps in [2u32, 8] {
        for contention in [false, true] {
            let mut nodes = vec![
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
            ];
            for node in &mut nodes {
                node.net = node.net.with_bandwidth(8.0);
            }
            let cfg = ClusterConfig::builder()
                .nodes(nodes)
                .policy(RoutePolicy::SloAware)
                .serve(
                    ServeConfig::builder()
                        .clock(ClockKind::Virtual)
                        .scheduler(SchedulerSpec::Fixed { batch: 4, m_c: 2 })
                        .admission(None)
                        .queue_capacity(4096)
                        .build()
                        .unwrap(),
                )
                .frontend(FrontEndConfig {
                    contention_pricing: contention,
                    ..Default::default()
                })
                .build()
                .unwrap();
            let load = LoadGenConfig::builder()
                .rps(80.0)
                .seconds(10.0)
                .seed(0xBCE)
                .slo_scale(3.0)
                .session(Some(SessionSpec {
                    decode_steps,
                    ttft_slo_scale: 2.0,
                    tpot_ms: 300.0,
                }))
                .build()
                .unwrap();
            let t0 = std::time::Instant::now();
            let report = run_cluster(&cfg, &load).expect("llm bench run");
            let wall_s = t0.elapsed().as_secs_f64();
            let steps = report.frontend.session_steps;
            let steps_per_sec = steps as f64 / wall_s.max(1e-9);
            let ttft_p95 = p95(report.metrics.outcomes().iter()
                .filter(|o| step_of(o.id) == 0)
                .map(|o| o.e2e_ms)
                .collect());
            let tpot_p95 = p95(report.metrics.outcomes().iter()
                .filter(|o| step_of(o.id) > 0)
                .map(|o| o.e2e_ms)
                .collect());
            println!(
                "{decode_steps:>2} steps  pricing {}  {steps:>7} spawned  \
                 {steps_per_sec:>10.0} steps/s  ttft p95 {ttft_p95:>8.2} ms  \
                 tpot p95 {tpot_p95:>8.2} ms",
                if contention { "on " } else { "off" }
            );
            llm.push(obj(vec![
                ("decode_steps", num(decode_steps as f64)),
                ("contention_pricing",
                 s(if contention { "on" } else { "off" })),
                ("sessions", num(report.metrics.sessions_started() as f64)),
                ("steps_spawned", num(steps as f64)),
                ("steps_per_sec", num(steps_per_sec)),
                ("ttft_p95_ms", num(ttft_p95)),
                ("tpot_p95_ms", num(tpot_p95)),
                ("ttft_misses", num(report.metrics.ttft_misses() as f64)),
                ("tpot_misses", num(report.metrics.tpot_misses() as f64)),
            ]));
        }
    }
    sections.push(("llm_serving", arr(llm)));

    // ---------------------------------------------------------------
    // Emit BENCH_hotpath.json at the repo root.
    // ---------------------------------------------------------------
    let mut fields: Vec<(&str, Json)> = vec![
        ("bench", s("hotpath_engine")),
        ("schema_version", num(1.0)),
        ("note", s("regenerate with: cd rust && cargo bench --bench \
                    hotpath_engine (release profile, lto=thin)")),
        // Acceptance targets travel with every regeneration so re-runs
        // never silently drop them. The serving ratio has no in-binary
        // seed counterpart (the seed tree shipped no manifest and is
        // unbuildable); it is proxied by the component speedups above,
        // while the SAC ratio IS measured directly (update_batch_alloc
        // is the seed path).
        ("targets", obj(vec![
            ("sac_update_step_speedup_vs_seed", num(2.0)),
            ("sim_serving_slots_per_sec_speedup_vs_seed", num(1.5)),
            ("sim_serving_measurement", s(
                "proxy: seed tree unbuildable (no manifest); compare \
                 aggregates.*_speedup + nn_sac.sac_update_speedup_vs_seed, \
                 and track sim_serving.slots_per_sec across commits")),
        ])),
    ];
    fields.extend(sections);
    let json = obj(fields);
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_hotpath.json"
    } else {
        "BENCH_hotpath.json"
    };
    std::fs::write(path, json.to_string() + "\n").expect("write bench json");
    println!("\nhotpath_engine OK — wrote {path}");
}
